//! Coordinator: trainers (VQ-GNN + the four baselines), optimizers, metrics
//! and evaluation — everything that owns cross-batch state.

pub mod checkpoint;
pub mod edge_trainer;
pub mod metrics;
pub mod opt;
pub mod vq_trainer;

use crate::runtime::manifest::ArtifactSpec;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Initialize the artifact's `param.*` inputs: Glorot-uniform for matrices,
/// scaled normal for attention vectors, zeros for biases.  Order matches the
/// artifact signature (and therefore its `grad.*` outputs).
pub fn init_params(spec: &ArtifactSpec, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ 0x9A7A);
    let mut out = Vec::new();
    for t in &spec.inputs {
        if !t.name.starts_with("param.") {
            continue;
        }
        let n = t.numel();
        let data = if t.name.ends_with(".bias") {
            vec![0.0f32; n]
        } else if t.name.contains(".a_src") || t.name.contains(".a_dst") {
            (0..n).map(|_| 0.1 * rng.gauss_f32()).collect()
        } else {
            // matrices: last two dims are (fan_in, fan_out); leading dims
            // (attention heads) don't change the per-matrix fans
            let d = t.shape.len();
            let (fi, fo) = if d >= 2 {
                (t.shape[d - 2], t.shape[d - 1])
            } else {
                (n, n)
            };
            let lim = (6.0 / (fi + fo) as f32).sqrt();
            (0..n).map(|_| (2.0 * rng.f32() - 1.0) * lim).collect()
        };
        out.push(Tensor::from_f32(&t.shape, data));
    }
    out
}

/// Lipschitz control for learnable convolutions (paper App. E / [47],
/// realized as norm clipping of the attention vectors): keeps the error
/// bounds of Thm. 2 meaningful for GAT / Transformer backbones.
pub fn lipschitz_clip(spec: &ArtifactSpec, params: &mut [Tensor], clip: f32) {
    let names: Vec<&str> = spec
        .inputs
        .iter()
        .filter(|t| t.name.starts_with("param."))
        .map(|t| t.name.as_str())
        .collect();
    for (name, p) in names.iter().zip(params.iter_mut()) {
        if name.contains(".a_src") || name.contains(".a_dst")
            || name.contains(".wq") || name.contains(".wk")
        {
            let norm: f32 = p.f.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > clip {
                let s = clip / norm;
                for x in p.f.iter_mut() {
                    *x *= s;
                }
            }
        }
    }
}

/// Gather feature rows of `nodes` into a (b, f) tensor.
pub fn gather_features(features: &[f32], f: usize, nodes: &[u32]) -> Tensor {
    let mut data = Vec::with_capacity(nodes.len() * f);
    for &v in nodes {
        data.extend_from_slice(&features[v as usize * f..(v as usize + 1) * f]);
    }
    Tensor::from_f32(&[nodes.len(), f], data)
}

/// Running throughput/bytes statistics for a training run.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    pub steps: u64,
    pub train_secs: f64,
    pub loss_last: f32,
    /// peak bytes = params + opt state + largest single-step (in + out)
    pub peak_step_bytes: u64,
    pub messages_per_step: u64,
    pub nodes_per_step: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn init_params_match_spec_order_and_shapes() {
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(dir).unwrap();
        for name in ["vq_train_tiny_sim_gcn", "vq_train_tiny_sim_gat"] {
            let spec = man.artifact(name).unwrap();
            let params = init_params(spec, 1);
            let pspecs: Vec<_> = spec
                .inputs
                .iter()
                .filter(|t| t.name.starts_with("param."))
                .collect();
            assert_eq!(params.len(), pspecs.len());
            for (p, s) in params.iter().zip(&pspecs) {
                assert_eq!(p.shape, s.shape, "{}", s.name);
                assert!(p.f.iter().all(|x| x.is_finite()));
                if s.name.ends_with(".bias") {
                    assert!(p.f.iter().all(|&x| x == 0.0));
                } else {
                    assert!(p.f.iter().any(|&x| x != 0.0), "{}", s.name);
                }
            }
        }
    }

    #[test]
    fn lipschitz_clip_bounds_attention_norms() {
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(dir).unwrap();
        let spec = man.artifact("vq_train_tiny_sim_gat").unwrap();
        let mut params = init_params(spec, 2);
        for p in params.iter_mut() {
            for x in p.f.iter_mut() {
                *x *= 100.0;
            }
        }
        lipschitz_clip(spec, &mut params, 4.0);
        let names: Vec<&str> = spec
            .inputs
            .iter()
            .filter(|t| t.name.starts_with("param."))
            .map(|t| t.name.as_str())
            .collect();
        for (n, p) in names.iter().zip(&params) {
            if n.contains(".a_src") || n.contains(".a_dst") {
                let norm: f32 = p.f.iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!(norm <= 4.0 + 1e-4, "{n}: {norm}");
            }
        }
    }

    #[test]
    fn gather_features_rows() {
        let feats = vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0];
        let t = gather_features(&feats, 2, &[2, 0]);
        assert_eq!(t.f, vec![20.0, 21.0, 0.0, 1.0]);
    }
}
