//! Coordinator: trainers (VQ-GNN + the four baselines), optimizers, metrics
//! and evaluation — everything that owns cross-batch state.

pub mod checkpoint;
pub mod edge_trainer;
pub mod metrics;
pub mod opt;
pub mod vq_trainer;

use crate::runtime::manifest::ArtifactSpec;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Initialize the artifact's `param.*` inputs: Glorot-uniform for matrices,
/// scaled normal for attention vectors, zeros for biases.  Order matches the
/// artifact signature (and therefore its `grad.*` outputs).
pub fn init_params(spec: &ArtifactSpec, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ 0x9A7A);
    let mut out = Vec::new();
    for t in &spec.inputs {
        if !t.name.starts_with("param.") {
            continue;
        }
        let n = t.numel();
        let data = if t.name.ends_with(".bias") {
            vec![0.0f32; n]
        } else if t.name.contains(".a_src") || t.name.contains(".a_dst") {
            (0..n).map(|_| 0.1 * rng.gauss_f32()).collect()
        } else {
            // matrices: last two dims are (fan_in, fan_out); leading dims
            // (attention heads) don't change the per-matrix fans
            let d = t.shape.len();
            let (fi, fo) = if d >= 2 {
                (t.shape[d - 2], t.shape[d - 1])
            } else {
                (n, n)
            };
            let lim = (6.0 / (fi + fo) as f32).sqrt();
            (0..n).map(|_| (2.0 * rng.f32() - 1.0) * lim).collect()
        };
        out.push(Tensor::from_f32(&t.shape, data));
    }
    out
}

/// Lipschitz control for learnable convolutions (paper App. E / [47],
/// realized as norm clipping of the attention vectors): keeps the error
/// bounds of Thm. 2 meaningful for GAT / Transformer backbones.
pub fn lipschitz_clip(spec: &ArtifactSpec, params: &mut [Tensor], clip: f32) {
    let names: Vec<&str> = spec
        .inputs
        .iter()
        .filter(|t| t.name.starts_with("param."))
        .map(|t| t.name.as_str())
        .collect();
    for (name, p) in names.iter().zip(params.iter_mut()) {
        if name.contains(".a_src") || name.contains(".a_dst")
            || name.contains(".wq") || name.contains(".wk")
        {
            let norm: f32 = p.f.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > clip {
                let s = clip / norm;
                for x in p.f.iter_mut() {
                    *x *= s;
                }
            }
        }
    }
}

/// Gather feature rows of `nodes` into a caller-owned `(b, f)` buffer
/// (every element overwritten) — sessions rebuild their `xb`/`x` input
/// slot in place each batch.
pub fn gather_features_into(features: &[f32], f: usize, nodes: &[u32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), nodes.len() * f);
    for (i, &v) in nodes.iter().enumerate() {
        out[i * f..(i + 1) * f]
            .copy_from_slice(&features[v as usize * f..(v as usize + 1) * f]);
    }
}

/// Allocating wrapper of [`gather_features_into`].
pub fn gather_features(features: &[f32], f: usize, nodes: &[u32]) -> Tensor {
    let mut data = vec![0.0f32; nodes.len() * f];
    gather_features_into(features, f, nodes, &mut data);
    Tensor::from_f32(&[nodes.len(), f], data)
}

/// One typed input slot of a trainer session — the per-step classification
/// the old `assemble()` loops re-derived from slot *names* every batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum InSlot {
    /// `xb` (VQ paths) or `x` (edge paths): gathered feature rows.
    X,
    Y,
    WLoss,
    Psrc,
    Pdst,
    Py,
    Pw,
    Esrc,
    Edst,
    Ecoef,
    /// `param.*` input number `i` (in signature order).
    Param(usize),
    /// Per-layer VQ context — handled by the layer pass via [`LayerIn`].
    Ctx,
}

/// Per-layer VQ-context input indices of a session (resolved once).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct LayerIn {
    pub c_in: Option<usize>,
    pub c_out: Option<usize>,
    pub ct_out: Option<usize>,
    pub mask_in: Option<usize>,
    pub m_out: Option<usize>,
    pub m_out_t: Option<usize>,
    pub cnt_out: Option<usize>,
    pub cw: Option<usize>,
    pub cww: Option<usize>,
    pub mean: Option<usize>,
    pub var: Option<usize>,
}

/// A trainer's persistent binding to one artifact: preallocated input
/// tensors rewritten in place every batch, output tensors rewritten in
/// place by `Runtime::execute_into`, and the slot classification resolved
/// once at construction.  Holding the session across steps is what turns
/// the old assemble-allocate-execute-drop cycle into a zero-allocation
/// steady state on the native backend.
pub(crate) struct Session {
    pub inputs: Vec<Tensor>,
    pub outputs: Vec<Tensor>,
    pub slots: Vec<InSlot>,
    pub lslots: Vec<LayerIn>,
    /// Train-artifact output indices of the per-layer VQ triple.
    pub o_xfeat: Vec<usize>,
    pub o_gvec: Vec<usize>,
    pub o_assign: Vec<usize>,
}

impl Session {
    /// Resolve an artifact's signature into a session (zero-filled input
    /// tensors + typed slots).  Unknown input names are a hard error — the
    /// same contract the old per-step `assemble` enforced, moved to
    /// construction time.
    pub(crate) fn for_artifact(spec: &ArtifactSpec) -> anyhow::Result<Session> {
        use crate::util::tensor::DType;
        let mut slots = Vec::with_capacity(spec.inputs.len());
        let mut lslots = vec![LayerIn::default(); spec.plan.len()];
        let mut pi = 0usize;
        for (idx, ts) in spec.inputs.iter().enumerate() {
            let name = ts.name.as_str();
            let slot = match name {
                "xb" | "x" => InSlot::X,
                "y" => InSlot::Y,
                "wloss" => InSlot::WLoss,
                "psrc" => InSlot::Psrc,
                "pdst" => InSlot::Pdst,
                "py" => InSlot::Py,
                "pw" => InSlot::Pw,
                "esrc" => InSlot::Esrc,
                "edst" => InSlot::Edst,
                "ecoef" => InSlot::Ecoef,
                _ if name.starts_with("param.") => {
                    let s = InSlot::Param(pi);
                    pi += 1;
                    s
                }
                _ => {
                    let (lstr, field) = name
                        .split_once('.')
                        .ok_or_else(|| anyhow::anyhow!("unknown input {name}"))?;
                    let l: usize = lstr[1..]
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad layer index in input {name}"))?;
                    let ls = lslots
                        .get_mut(l)
                        .ok_or_else(|| anyhow::anyhow!("input {name} out of layer range"))?;
                    match field {
                        "c_in" => ls.c_in = Some(idx),
                        "c_out" => ls.c_out = Some(idx),
                        "ct_out" => ls.ct_out = Some(idx),
                        "mask_in" => ls.mask_in = Some(idx),
                        "m_out" => ls.m_out = Some(idx),
                        "m_out_t" => ls.m_out_t = Some(idx),
                        "cnt_out" => ls.cnt_out = Some(idx),
                        "cw" => ls.cw = Some(idx),
                        "cww" => ls.cww = Some(idx),
                        "mean" => ls.mean = Some(idx),
                        "var" => ls.var = Some(idx),
                        other => anyhow::bail!("unknown ctx field {other}"),
                    }
                    InSlot::Ctx
                }
            };
            slots.push(slot);
        }
        let inputs = spec
            .inputs
            .iter()
            .map(|ts| match ts.dtype {
                DType::F32 => Tensor::zeros(&ts.shape),
                DType::I32 => Tensor::from_i32(&ts.shape, vec![0; ts.numel()]),
            })
            .collect();
        let (mut o_xfeat, mut o_gvec, mut o_assign) = (Vec::new(), Vec::new(), Vec::new());
        for l in 0..spec.plan.len() {
            if let Some(x) = spec.output_index(&format!("l{l}.xfeat")) {
                o_xfeat.push(x);
            }
            if let Some(g) = spec.output_index(&format!("l{l}.gvec")) {
                o_gvec.push(g);
            }
            if let Some(a) = spec.output_index(&format!("l{l}.assign")) {
                o_assign.push(a);
            }
        }
        Ok(Session {
            inputs,
            outputs: Vec::new(),
            slots,
            lslots,
            o_xfeat,
            o_gvec,
            o_assign,
        })
    }
}

/// Reusable link-pair buffers (`psrc`/`pdst`/`py`/`pw`), filled per batch
/// and copied into the session's input slots.
#[derive(Default)]
pub(crate) struct PairBuf {
    pub psrc: Vec<i32>,
    pub pdst: Vec<i32>,
    pub py: Vec<f32>,
    pub pw: Vec<f32>,
}

/// Sample link-prediction training pairs over `nodes` (graph-global ids;
/// pair endpoints are LOCAL row indices): positives are intra-batch arcs,
/// negatives random intra-batch pairs; padding pairs get weight 0.  The
/// rng draw order matches the pre-session assemble paths exactly, so
/// trajectories are unchanged.
pub(crate) fn fill_link_pairs(
    graph: &crate::graph::Graph,
    rng: &mut Rng,
    nodes: &[u32],
    p: usize,
    train: bool,
    buf: &mut PairBuf,
) {
    let nl = nodes.len();
    buf.psrc.clear();
    buf.psrc.resize(p, 0);
    buf.pdst.clear();
    buf.pdst.resize(p, 0);
    buf.py.clear();
    buf.py.resize(p, 0.0);
    buf.pw.clear();
    buf.pw.resize(p, 0.0);
    let mut pos = Vec::new();
    if train {
        let mut local = std::collections::HashMap::new();
        for (i, &g) in nodes.iter().enumerate() {
            local.insert(g, i as i32);
        }
        'outer: for (i, &g) in nodes.iter().enumerate() {
            for &u in graph.in_neighbors(g as usize) {
                if let Some(&lu) = local.get(&u) {
                    pos.push((lu, i as i32));
                    if pos.len() >= p / 2 {
                        break 'outer;
                    }
                }
            }
        }
    }
    for (i, &(u, v)) in pos.iter().enumerate() {
        buf.psrc[i] = u;
        buf.pdst[i] = v;
        buf.py[i] = 1.0;
        buf.pw[i] = 1.0;
    }
    for i in pos.len()..p {
        buf.psrc[i] = rng.below(nl) as i32;
        buf.pdst[i] = rng.below(nl) as i32;
        buf.pw[i] = if train { 1.0 } else { 0.0 };
    }
}

/// Running throughput/bytes statistics for a training run.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    pub steps: u64,
    pub train_secs: f64,
    pub loss_last: f32,
    /// peak bytes = params + opt state + largest single-step (in + out)
    pub peak_step_bytes: u64,
    pub messages_per_step: u64,
    pub nodes_per_step: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn init_params_match_spec_order_and_shapes() {
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(dir).unwrap();
        for name in ["vq_train_tiny_sim_gcn", "vq_train_tiny_sim_gat"] {
            let spec = man.artifact(name).unwrap();
            let params = init_params(spec, 1);
            let pspecs: Vec<_> = spec
                .inputs
                .iter()
                .filter(|t| t.name.starts_with("param."))
                .collect();
            assert_eq!(params.len(), pspecs.len());
            for (p, s) in params.iter().zip(&pspecs) {
                assert_eq!(p.shape, s.shape, "{}", s.name);
                assert!(p.f.iter().all(|x| x.is_finite()));
                if s.name.ends_with(".bias") {
                    assert!(p.f.iter().all(|&x| x == 0.0));
                } else {
                    assert!(p.f.iter().any(|&x| x != 0.0), "{}", s.name);
                }
            }
        }
    }

    #[test]
    fn lipschitz_clip_bounds_attention_norms() {
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(dir).unwrap();
        let spec = man.artifact("vq_train_tiny_sim_gat").unwrap();
        let mut params = init_params(spec, 2);
        for p in params.iter_mut() {
            for x in p.f.iter_mut() {
                *x *= 100.0;
            }
        }
        lipschitz_clip(spec, &mut params, 4.0);
        let names: Vec<&str> = spec
            .inputs
            .iter()
            .filter(|t| t.name.starts_with("param."))
            .map(|t| t.name.as_str())
            .collect();
        for (n, p) in names.iter().zip(&params) {
            if n.contains(".a_src") || n.contains(".a_dst") {
                let norm: f32 = p.f.iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!(norm <= 4.0 + 1e-4, "{n}: {norm}");
            }
        }
    }

    #[test]
    fn gather_features_rows() {
        let feats = vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0];
        let t = gather_features(&feats, 2, &[2, 0]);
        assert_eq!(t.f, vec![20.0, 21.0, 0.0, 1.0]);
    }
}
