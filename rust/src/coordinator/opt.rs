//! Optimizers.  VQ-GNN uses RMSprop (paper App. E: the EMA-smoothed gradient
//! codewords are incompatible with Adam's cumulative history); the sampling
//! baselines use Adam per the OGB reference setups (App. F).

use crate::util::tensor::Tensor;

pub trait Optimizer {
    fn step(&mut self, params: &mut [Tensor], grads: &[&Tensor]);
}

pub struct RmsProp {
    pub lr: f32,
    pub alpha: f32,
    pub eps: f32,
    v: Vec<Vec<f32>>,
}

impl RmsProp {
    pub fn new(lr: f32, alpha: f32, params: &[Tensor]) -> RmsProp {
        RmsProp {
            lr,
            alpha,
            eps: 1e-8,
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }
}

impl Optimizer for RmsProp {
    fn step(&mut self, params: &mut [Tensor], grads: &[&Tensor]) {
        assert_eq!(params.len(), grads.len());
        for (pi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let v = &mut self.v[pi];
            for i in 0..p.f.len() {
                let gi = g.f[i];
                v[i] = self.alpha * v[i] + (1.0 - self.alpha) * gi * gi;
                p.f[i] -= self.lr * gi / (v[i].sqrt() + self.eps);
            }
        }
    }
}

pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32, params: &[Tensor]) -> Adam {
        Adam {
            lr,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            t: 0,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Tensor], grads: &[&Tensor]) {
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        for (pi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let (m, v) = (&mut self.m[pi], &mut self.v[pi]);
            for i in 0..p.f.len() {
                let gi = g.f[i];
                m[i] = self.b1 * m[i] + (1.0 - self.b1) * gi;
                v[i] = self.b2 * v[i] + (1.0 - self.b2) * gi * gi;
                p.f[i] -= self.lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + self.eps);
            }
        }
    }
}

/// Optimizer state bytes (memory-meter component for Table 3).
pub fn opt_state_bytes(params: &[Tensor], slots: usize) -> u64 {
    params.iter().map(|p| (p.numel() * 4 * slots) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // f(x) = ||x||²/2, ∇ = x
        Tensor::from_f32(&p.shape, p.f.clone())
    }

    #[test]
    fn rmsprop_descends_quadratic() {
        let mut params = vec![Tensor::from_f32(&[4], vec![1.0, -2.0, 3.0, -4.0])];
        let mut opt = RmsProp::new(0.05, 0.9, &params);
        for _ in 0..200 {
            let g = quad_grad(&params[0]);
            opt.step(&mut params, &[&g]);
        }
        let norm: f32 = params[0].f.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm < 0.1, "norm {norm}");
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut params = vec![Tensor::from_f32(&[4], vec![1.0, -2.0, 3.0, -4.0])];
        let mut opt = Adam::new(0.05, &params);
        for _ in 0..300 {
            let g = quad_grad(&params[0]);
            opt.step(&mut params, &[&g]);
        }
        let norm: f32 = params[0].f.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm < 0.1, "norm {norm}");
    }
}
