//! VQ-GNN trainer (paper Alg. 1): mini-batch sampling → sketch building →
//! one fused train-step execution (Eq. 6/7 + in-graph FINDNEAREST) →
//! RMSprop + VQ EMA update + assignment-table refresh.
//!
//! The trainer holds a persistent [`Session`] per artifact: input tensors
//! are allocated once and rewritten in place every batch (sketches, labels,
//! codeword tables, parameter copies), and outputs are rewritten in place
//! by `Runtime::execute_into` — the steady-state step allocates nothing on
//! the assembly/compute boundary beyond the sampled batch itself.
//!
//! **Pipelined batch assembly**: while the compiled executor runs step `t`,
//! a `util::par::join2` worker samples batch `t+1` and gathers its feature
//! rows (the parts of assembly that depend only on static data and the
//! batcher/RNG stream).  Sketch building stays on the critical path by
//! design: Alg. 1's data dependence means batch `t+1`'s sketches consume
//! the assignment tables step `t` just refreshed, so prefetching them would
//! change the trajectory.  The overlapped schedule is bit-identical to the
//! serial one (asserted by `tests/plan_executor.rs`); it is disabled for
//! link-task datasets, whose evaluation path shares the trainer RNG that
//! orders prefetch draws.

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::opt::Optimizer;
use crate::coordinator::{
    fill_link_pairs, gather_features_into, init_params, lipschitz_clip, opt, InSlot, PairBuf,
    RunStats, Session,
};
use crate::datasets::{Dataset, Split};
use crate::graph::Conv;
use crate::obs;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::{Artifact, Runtime};
use crate::sampler::{NodeBatcher, NodeStrategy};
use crate::shard::{ShardExec, ShardPlan};
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::tensor::{self, Tensor};
use crate::vq::sketch::{build_cnt_out_into, build_fixed_into, build_learnable_into, SketchScratch};
use crate::vq::VqModel;

/// Global gradient-scale cap for the learnable-convolution backbones.  In
/// practice attention gradients sit well above 1 every step (the decoupled
/// Eq. 7 messages are unnormalized), so this acts as gradient
/// *normalization* — each RMSprop step sees a unit-norm gradient direction,
/// which makes the update scale-free and immune to the occasional 1000×
/// Eq. 7 spike (verified over the exact training trajectories the
/// loss-descent tests assert).
const GRAD_NORM_CAP: f64 = 1.0;

/// L2 norm over the whole grad.* tail, accumulated in f64.
fn global_grad_norm(grads: &[Tensor]) -> f64 {
    grads
        .iter()
        .flat_map(|t| t.f.iter())
        .map(|&x| x as f64 * x as f64)
        .sum::<f64>()
        .sqrt()
}

/// Cap gradient-codeword rows at 10× the upper-median *nonzero* row L2 norm
/// before they enter the codebook EMA (App. E: the smoothed gradient
/// codewords are only meaningful if no single row dominates the cluster
/// statistics).  Zero rows — loss-masked validation/test/padding nodes,
/// which can be more than half the batch at the last layer — are excluded
/// from the median so they cannot collapse the cap onto the real rows.
/// Caps in place: the rows live in the session's (step-scoped) output
/// buffer, so no copy is taken on any path.
fn winsorize_rows_in_place(gvec: &mut Tensor) {
    let (b, gdim) = (gvec.shape[0], gvec.shape[1]);
    let norms: Vec<f64> = (0..b)
        .map(|i| {
            gvec.f[i * gdim..(i + 1) * gdim]
                .iter()
                .map(|&x| x as f64 * x as f64)
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    let mut nonzero: Vec<f64> = norms.iter().copied().filter(|&n| n > 0.0).collect();
    if nonzero.is_empty() {
        return;
    }
    nonzero.sort_by(f64::total_cmp);
    let cap = 10.0 * nonzero[nonzero.len() / 2];
    for i in 0..b {
        if norms[i] > cap {
            let s = (cap / norms[i]) as f32;
            for x in gvec.f[i * gdim..(i + 1) * gdim].iter_mut() {
                *x *= s;
            }
        }
    }
}

/// `VQ_GNN_PIPELINE=0|off|false` disables the overlapped prep stage.
pub(crate) fn pipeline_env_enabled() -> bool {
    !matches!(
        std::env::var("VQ_GNN_PIPELINE").as_deref(),
        Ok("0") | Ok("off") | Ok("false")
    )
}

/// Stage-timer handles for a training loop, resolved once from an
/// [`obs::Registry`] by `set_metrics`.  Default-disabled: the
/// un-instrumented trainer takes no clock reads (each record is one
/// `Option` test).  Histograms are atomic, so the prefetch worker can
/// record `sample`/`gather` from its own thread.
#[derive(Clone, Default)]
pub struct TrainMetrics {
    pub(crate) sample: obs::HistHandle,
    pub(crate) gather: obs::HistHandle,
    pub(crate) exec: obs::HistHandle,
    pub(crate) vq_update: obs::HistHandle,
}

impl TrainMetrics {
    pub fn wire(reg: &obs::Registry) -> TrainMetrics {
        TrainMetrics {
            sample: reg.hist("train_sample"),
            gather: reg.hist("train_gather"),
            exec: reg.hist("train_exec"),
            vq_update: reg.hist("train_vq_update"),
        }
    }
}

/// A prefetched batch: the sampled node ids plus their gathered feature
/// rows — everything batch assembly can compute before step `t`'s VQ
/// updates land.
struct PrepBatch {
    batch: Vec<u32>,
    pad: usize,
    xb: Vec<f32>,
}

/// Rewrite a session's input slots in place for one batch.  The rng is
/// only drawn for link pairs, FIRST — the same draw order as the
/// pre-session assemble, so trajectories are unchanged.
#[allow(clippy::too_many_arguments)]
fn fill_session(
    sess: &mut Session,
    spec: &ArtifactSpec,
    ds: &Dataset,
    vq: &VqModel,
    params: &[Tensor],
    conv: Option<Conv>,
    scratch: &mut SketchScratch,
    rng: &mut Rng,
    pairs: &mut PairBuf,
    batch: &[u32],
    pad: usize,
    train: bool,
    xb_pre: Option<&[f32]>,
) -> Result<()> {
    let b = batch.len();
    let f = ds.cfg.f_in_pad;
    if sess.slots.contains(&InSlot::Psrc) {
        let p = spec.inputs[spec.input_index("psrc").unwrap()].numel();
        fill_link_pairs(&ds.graph, rng, batch, p, train, pairs);
    }
    let Session { inputs, slots, lslots, .. } = sess;
    for (idx, slot) in slots.iter().enumerate() {
        match *slot {
            InSlot::X => {
                if let Some(x) = xb_pre {
                    inputs[idx].f.copy_from_slice(x);
                } else {
                    gather_features_into(&ds.features, f, batch, &mut inputs[idx].f);
                }
            }
            InSlot::Y => {
                if ds.cfg.multilabel {
                    let c = ds.cfg.n_classes;
                    let data = &mut inputs[idx].f;
                    for (i, &v) in batch.iter().enumerate() {
                        data[i * c..(i + 1) * c].copy_from_slice(
                            &ds.labels_multi[v as usize * c..(v as usize + 1) * c],
                        );
                    }
                } else {
                    let data = &mut inputs[idx].i;
                    for (i, &v) in batch.iter().enumerate() {
                        data[i] = ds.labels[v as usize];
                    }
                }
            }
            InSlot::WLoss => {
                let w = &mut inputs[idx].f;
                for (i, &v) in batch.iter().enumerate() {
                    w[i] = if train && ds.split[v as usize] != Split::Train {
                        0.0
                    } else {
                        1.0
                    };
                }
                for i in (b - pad)..b {
                    w[i] = 0.0;
                }
            }
            InSlot::Psrc => inputs[idx].i.copy_from_slice(&pairs.psrc),
            InSlot::Pdst => inputs[idx].i.copy_from_slice(&pairs.pdst),
            InSlot::Py => inputs[idx].f.copy_from_slice(&pairs.py),
            InSlot::Pw => inputs[idx].f.copy_from_slice(&pairs.pw),
            InSlot::Param(pi) => inputs[idx].f.copy_from_slice(&params[pi].f),
            InSlot::Ctx => {}
            InSlot::Esrc | InSlot::Edst | InSlot::Ecoef => {
                anyhow::bail!("edge-list input in a VQ artifact ({})", spec.name)
            }
        }
    }
    for (l, ls) in lslots.iter().enumerate() {
        let layer = &vq.layers[l];
        if let (Some(ci), Some(co), Some(ct)) = (ls.c_in, ls.c_out, ls.ct_out) {
            let (tc, to, tt) = tensor::mut3(inputs, ci, co, ct);
            build_fixed_into(
                &ds.graph,
                conv.expect("fixed-conv artifact without a fixed conv"),
                batch,
                layer,
                scratch,
                &mut tc.f,
                &mut to.f,
                &mut tt.f,
            );
        }
        if let (Some(mi), Some(mo), Some(mt)) = (ls.mask_in, ls.m_out, ls.m_out_t) {
            let (tm, to, tt) = tensor::mut3(inputs, mi, mo, mt);
            build_learnable_into(
                &ds.graph, batch, layer, scratch, &mut tm.f, &mut to.f, &mut tt.f,
            );
        }
        if let Some(i) = ls.cnt_out {
            build_cnt_out_into(batch, layer, scratch, &mut inputs[i].f);
        }
        if let Some(i) = ls.cw {
            layer.cw_into(&mut inputs[i].f);
        }
        if let Some(i) = ls.cww {
            layer.cww_into(&mut inputs[i].f);
        }
        if let Some(i) = ls.mean {
            layer.mean_into(&mut inputs[i].f);
        }
        if let Some(i) = ls.var {
            layer.var_into(&mut inputs[i].f);
        }
    }
    Ok(())
}

pub struct VqTrainer {
    pub train_art: Rc<Artifact>,
    pub infer_art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub vq: VqModel,
    pub params: Vec<Tensor>,
    opt: opt::RmsProp,
    batcher: NodeBatcher,
    scratch: SketchScratch,
    rng: Rng,
    gamma: f32,
    beta: f32,
    weight_clip: f32,
    train_io: Session,
    infer_io: Session,
    pairs: PairBuf,
    /// Overlapped prep stage on/off (see module docs; off for link tasks).
    pipeline: bool,
    prefetched: Option<PrepBatch>,
    pub stats: RunStats,
    metrics: TrainMetrics,
    /// Per-layer (perplexity, dead-code) gauges; empty when unwired.
    health_gauges: Vec<(obs::GaugeHandle, obs::GaugeHandle)>,
    /// Sharded EMA coordinator (`--shards S`); `None` = unsharded path.
    /// The sharded trajectory is bit-identical at any S (see
    /// `crate::shard` docs), so this is purely an execution-layout knob.
    shards: Option<ShardExec>,
    /// Dead-code expiry knob: `(threshold, rng)`.  `None` (default) keeps
    /// the trajectory bit-identical to the NaN-guard-only update.
    expiry: Option<(f32, Rng)>,
}

impl VqTrainer {
    /// `suffix` selects ablation artifacts ("", "_l2", "_k64", "_b256", ...).
    pub fn new(rt: &mut Runtime, man: &Manifest, ds: Rc<Dataset>,
               model_name: &str, suffix: &str, strategy: NodeStrategy,
               seed: u64) -> Result<VqTrainer> {
        let train_name = format!("vq_train_{}_{}{}", ds.cfg.name, model_name, suffix);
        let infer_name = format!("vq_infer_{}_{}{}", ds.cfg.name, model_name, suffix);
        let train_art = rt.load(man, &train_name)?;
        let infer_art = rt.load(man, &infer_name)?;
        let spec = &train_art.spec;
        let params = init_params(spec, seed);
        // Learnable convolutions step at lr/3: the Eq. 7 out-of-batch
        // gradient messages decouple raw attention scores from their own
        // denominators, so their early-training variance is higher than the
        // fixed convs' (bounded row-normalized coefficients) tolerate-ably
        // under the shared base lr.
        let lr = if matches!(model_name, "gat" | "txf") {
            man.train.lr / 3.0
        } else {
            man.train.lr
        };
        let opt = opt::RmsProp::new(lr as f32, man.train.rms_alpha as f32, &params);
        let vq = VqModel::init(&spec.plan, spec.k, ds.n(), seed);
        // transductive: batches over ALL nodes (loss masked to train nodes);
        // inductive: only training graphs' nodes are visible during training.
        let pool: Vec<u32> = if ds.cfg.inductive {
            ds.nodes_in_split(Split::Train)
        } else {
            (0..ds.n() as u32).collect()
        };
        let batcher = NodeBatcher::new(pool, spec.b, strategy);
        let scratch = SketchScratch::new(ds.n());
        let train_io = Session::for_artifact(spec)?;
        let infer_io = Session::for_artifact(&infer_art.spec)?;
        let pipeline = ds.cfg.task != "link" && pipeline_env_enabled();
        Ok(VqTrainer {
            train_art,
            infer_art,
            model_name: model_name.to_string(),
            vq,
            params,
            opt,
            batcher,
            scratch,
            rng: Rng::new(seed ^ 0x7141),
            gamma: man.train.gamma as f32,
            beta: man.train.beta as f32,
            weight_clip: man.train.weight_clip as f32,
            train_io,
            infer_io,
            pairs: PairBuf::default(),
            pipeline,
            prefetched: None,
            stats: RunStats::default(),
            metrics: TrainMetrics::default(),
            health_gauges: Vec::new(),
            shards: None,
            expiry: None,
            ds,
        })
    }

    /// Shard the VQ EMA update across `s` persistent workers (1 = the
    /// unsharded path).  The node→shard partition map is a contiguous
    /// range split over the dataset's nodes; the resulting trajectory is
    /// bit-identical to the unsharded one at any `s`.
    pub fn set_shards(&mut self, s: usize) {
        self.shards = if s <= 1 {
            None
        } else {
            Some(ShardExec::new(ShardPlan::contiguous(self.ds.n(), s)))
        };
    }

    /// Active shard count (1 when unsharded).
    pub fn shards(&self) -> usize {
        self.shards.as_ref().map_or(1, |e| e.shards())
    }

    /// The node→shard partition map, when sharded — checkpointed so a
    /// resumed run keeps the same table ownership.
    pub fn shard_plan(&self) -> Option<&ShardPlan> {
        self.shards.as_ref().map(|e| &e.plan)
    }

    /// Restore a checkpointed partition map (spins up its worker pool).
    pub fn set_shard_plan(&mut self, plan: Option<ShardPlan>) {
        self.shards = plan.map(ShardExec::new);
    }

    /// Enable dead-code expiry: clusters whose EMA count drops below
    /// `threshold` are re-seeded from current-batch rows (deterministic
    /// draws from a dedicated forked RNG).  Off by default — enabling it
    /// changes the trajectory (that is the point), but the sharded and
    /// unsharded paths still agree bit-for-bit because expiry runs on
    /// the coordinator after the merged refresh.
    pub fn set_dead_code_expiry(&mut self, threshold: Option<f32>) {
        self.expiry = threshold.map(|t| (t, self.rng.fork(0xDEAD)));
    }

    /// Wire stage timers (`train_sample`/`train_gather`/`train_exec`/
    /// `train_vq_update`) and per-layer VQ-health gauges
    /// (`vq_codebook_perplexity_l{l}`, `vq_dead_codes_l{l}` — from the
    /// branch-0 EMA masses) into `reg`.
    pub fn set_metrics(&mut self, reg: &obs::Registry) {
        self.metrics = TrainMetrics::wire(reg);
        self.health_gauges = (0..self.vq.layers.len())
            .map(|l| {
                (
                    reg.gauge(&format!("vq_codebook_perplexity_l{l}")),
                    reg.gauge(&format!("vq_dead_codes_l{l}")),
                )
            })
            .collect();
    }

    /// Toggle the overlapped prep stage (always off for link tasks, whose
    /// evaluation path shares the trainer rng).  The pipelined and serial
    /// schedules compute identical trajectories; the toggle exists for the
    /// parity tests and the allocation benchmarks.
    pub fn set_pipelined(&mut self, on: bool) {
        self.pipeline = on && self.ds.cfg.task != "link";
    }

    /// Whether the overlapped prep stage is active.
    pub fn pipelined(&self) -> bool {
        self.pipeline
    }

    fn conv_opt(&self) -> Option<Conv> {
        match self.model_name.as_str() {
            "gcn" => Some(Conv::GcnSym),
            "sage" => Some(Conv::SageMean),
            _ => None, // learnable convolutions build count sketches instead
        }
    }

    fn learnable(&self) -> bool {
        matches!(self.model_name.as_str(), "gat" | "txf")
    }

    /// Sample one batch and gather its feature rows — the prefetchable half
    /// of batch assembly (static data + the batcher/RNG stream only).
    /// Records `train_sample` / `train_gather` whether it runs inline or on
    /// the prefetch worker (the histogram cells are atomic).
    fn build_prep(
        batcher: &mut NodeBatcher,
        ds: &Dataset,
        mut rng: Rng,
        m: &TrainMetrics,
    ) -> PrepBatch {
        let span = m.sample.stage();
        let (batch, pad) = batcher.next_batch(&ds.graph, &mut rng);
        span.stop();
        let span = m.gather.stage();
        let f = ds.cfg.f_in_pad;
        let mut xb = vec![0.0f32; batch.len() * f];
        gather_features_into(&ds.features, f, &batch, &mut xb);
        span.stop();
        PrepBatch { batch, pad, xb }
    }

    pub fn train_step(&mut self, rt: &mut Runtime) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let ds = self.ds.clone();
        let art = self.train_art.clone();
        let prep = match self.prefetched.take() {
            Some(p) => p,
            None => {
                let rng = self.rng.fork(self.stats.steps);
                Self::build_prep(&mut self.batcher, &ds, rng, &self.metrics)
            }
        };
        let conv = self.conv_opt();
        let learnable = self.learnable();
        // synchronous half of assembly: sketches against the JUST-updated
        // assignment tables, codeword tensors, labels, params
        fill_session(
            &mut self.train_io,
            &art.spec,
            &ds,
            &self.vq,
            &self.params,
            conv,
            &mut self.scratch,
            &mut self.rng,
            &mut self.pairs,
            &prep.batch,
            prep.pad,
            true,
            Some(&prep.xb),
        )?;
        // step t computes while the prep worker samples + gathers batch t+1
        let exec_res = if self.pipeline {
            let prng = self.rng.fork(self.stats.steps + 1);
            let batcher = &mut self.batcher;
            let dsr: &Dataset = &ds;
            let io = &mut self.train_io;
            let (inputs, outputs) = (&io.inputs, &mut io.outputs);
            let m = &self.metrics;
            let (next, res) = par::join2(
                move || Self::build_prep(batcher, dsr, prng, m),
                move || {
                    let span = m.exec.stage();
                    let res = rt.execute_into(&art, inputs, outputs);
                    span.stop();
                    res
                },
            );
            self.prefetched = Some(next);
            res
        } else {
            let span = self.metrics.exec.stage();
            let res =
                rt.execute_into(&art, &self.train_io.inputs, &mut self.train_io.outputs);
            span.stop();
            res
        };
        exec_res?;
        let spec = &self.train_art.spec;
        let loss = self.train_io.outputs[0].f[0];
        // VQ EMA updates + assignment-table refresh per layer (Alg. 2).
        // Learnable convolutions winsorize the gradient rows first — in
        // place, in the session's output buffer: a single spiky ∂ℓ/∂num row
        // (attention-denominator conditioning) would otherwise poison its
        // cluster's EMA codeword for ~1/(1-γ) steps and get re-broadcast
        // into every later batch's Eq. 7 backward messages.
        {
            let span = self.metrics.vq_update.stage();
            let sess = &mut self.train_io;
            for l in 0..spec.plan.len() {
                let (xi, gi, ai) = (sess.o_xfeat[l], sess.o_gvec[l], sess.o_assign[l]);
                if learnable {
                    winsorize_rows_in_place(&mut sess.outputs[gi]);
                }
                // Sharded and unsharded EMA updates are bit-identical —
                // the shard coordinator merges the same per-chunk
                // partials in the same order (crate::shard docs).
                match &self.shards {
                    Some(exec) => exec.update_layer(
                        &mut self.vq.layers[l],
                        &prep.batch,
                        &sess.outputs[xi],
                        &sess.outputs[gi],
                        &sess.outputs[ai],
                        self.gamma,
                        self.beta,
                        &mut self.expiry,
                    ),
                    None => self.vq.layers[l].update_from_batch_expiring(
                        &prep.batch,
                        &sess.outputs[xi],
                        &sess.outputs[gi],
                        &sess.outputs[ai],
                        self.gamma,
                        self.beta,
                        &mut self.expiry,
                    ),
                }
            }
            // optimizer on the grad.* tail (ordered like params); attention
            // backbones normalize the global gradient scale (GRAD_NORM_CAP)
            // in place — the same Eq. 7 spikes that motivate the
            // winsorization also reach the parameter gradients of the lower
            // layers.
            let n_params = self.params.len();
            let start = sess.outputs.len() - n_params;
            if learnable {
                let norm = global_grad_norm(&sess.outputs[start..]);
                if norm > GRAD_NORM_CAP {
                    let s = (GRAD_NORM_CAP / norm) as f32;
                    for t in sess.outputs[start..].iter_mut() {
                        for x in t.f.iter_mut() {
                            *x *= s;
                        }
                    }
                }
            }
            let grads: Vec<&Tensor> = sess.outputs[start..].iter().collect();
            self.opt.step(&mut self.params, &grads);
            span.stop();
        }
        // VQ health after the EMA updates land (branch-0 masses; deeper
        // branches track the same assignment cardinalities)
        for (l, (perp, dead)) in self.health_gauges.iter().enumerate() {
            let (p, d) = obs::codebook_health(&self.vq.layers[l].branches[0].counts, 1e-3);
            perp.set(p);
            dead.set(d as f64);
        }
        if learnable {
            lipschitz_clip(spec, &mut self.params, self.weight_clip);
        }
        let step_bytes = spec.input_bytes() + spec.output_bytes()
            + opt::opt_state_bytes(&self.params, 1);
        self.stats.peak_step_bytes = self.stats.peak_step_bytes.max(step_bytes);
        self.stats.steps += 1;
        self.stats.loss_last = loss;
        self.stats.nodes_per_step = prep.batch.len() as u64;
        self.stats.messages_per_step = self.count_messages(&prep.batch);
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        Ok(loss)
    }

    /// Messages effectively preserved per step: ALL arcs into the batch
    /// (paper Fig. 1 — intra-batch exact + codeword-merged).
    fn count_messages(&self, batch: &[u32]) -> u64 {
        batch
            .iter()
            .map(|&v| self.ds.graph.in_degree(v as usize) as u64 + 1)
            .sum()
    }

    pub fn epoch(&mut self, rt: &mut Runtime) -> Result<f32> {
        let mut last = 0.0;
        for _ in 0..self.batcher.batches_per_epoch() {
            last = self.train_step(rt)?;
        }
        Ok(last)
    }

    /// Mini-batch inference over arbitrary nodes via the infer artifact's
    /// session; returns row-major (|nodes|, c) logits/embeddings.
    pub fn infer_nodes(&mut self, rt: &mut Runtime, nodes: &[u32]) -> Result<Vec<f32>> {
        let ds = self.ds.clone();
        let art = self.infer_art.clone();
        let b = art.spec.b;
        let c = art.spec.outputs[0].shape[1];
        let conv = self.conv_opt();
        let mut logits = vec![0.0f32; nodes.len() * c];
        let mut batch: Vec<u32> = Vec::with_capacity(b);
        let mut i = 0;
        while i < nodes.len() {
            let end = (i + b).min(nodes.len());
            batch.clear();
            batch.extend_from_slice(&nodes[i..end]);
            let real = batch.len();
            while batch.len() < b {
                batch.push(nodes[0]); // pad rows; outputs ignored
            }
            fill_session(
                &mut self.infer_io,
                &art.spec,
                &ds,
                &self.vq,
                &self.params,
                conv,
                &mut self.scratch,
                &mut self.rng,
                &mut self.pairs,
                &batch,
                0,
                false,
                None,
            )?;
            rt.execute_into(&art, &self.infer_io.inputs, &mut self.infer_io.outputs)?;
            logits[i * c..end * c].copy_from_slice(&self.infer_io.outputs[0].f[..real * c]);
            i = end;
        }
        Ok(logits)
    }

    /// Evaluate the task metric on a split (accuracy / micro-F1 / Hits@50).
    pub fn evaluate(&mut self, rt: &mut Runtime, split: Split) -> Result<f64> {
        use crate::coordinator::metrics;
        let ds = self.ds.clone();
        if ds.cfg.task == "link" {
            return self.evaluate_link(rt, split);
        }
        if ds.cfg.inductive && split != Split::Train {
            self.bootstrap_inductive(rt, split)?;
        }
        let nodes = ds.nodes_in_split(split);
        let logits = self.infer_nodes(rt, &nodes)?;
        let rows: Vec<usize> = (0..nodes.len()).collect();
        let c = ds.cfg.n_classes;
        if ds.cfg.multilabel {
            let mut tgt = vec![0.0f32; nodes.len() * c];
            for (i, &v) in nodes.iter().enumerate() {
                tgt[i * c..(i + 1) * c].copy_from_slice(
                    &ds.labels_multi[v as usize * c..(v as usize + 1) * c],
                );
            }
            Ok(metrics::micro_f1(&logits, c, &tgt, &rows))
        } else {
            let labels: Vec<i32> = nodes.iter().map(|&v| ds.labels[v as usize]).collect();
            Ok(metrics::accuracy(&logits, c, &labels, &rows))
        }
    }

    fn evaluate_link(&mut self, rt: &mut Runtime, split: Split) -> Result<f64> {
        use crate::coordinator::metrics;
        let ds = self.ds.clone();
        let all: Vec<u32> = (0..ds.n() as u32).collect();
        let h = self.infer_art.spec.outputs[0].shape[1];
        let emb = self.infer_nodes(rt, &all)?;
        let score = |u: u32, v: u32| -> f32 {
            emb[u as usize * h..(u as usize + 1) * h]
                .iter()
                .zip(&emb[v as usize * h..(v as usize + 1) * h])
                .map(|(x, y)| x * y)
                .sum()
        };
        let pos = if split == Split::Val { &ds.val_pos } else { &ds.test_pos };
        let pos_scores: Vec<f32> = pos.iter().map(|&(u, v)| score(u, v)).collect();
        let mut rng = Rng::new(0xBEEF);
        let neg_scores: Vec<f32> = (0..4096)
            .map(|_| score(rng.below(ds.n()) as u32, rng.below(ds.n()) as u32))
            .collect();
        Ok(metrics::hits_at_k(&pos_scores, &neg_scores, 50))
    }

    /// Inductive inference bootstrap (paper §6 "one extra step"): assign
    /// unseen nodes to their nearest codewords by *feature* columns — layer
    /// 0 from raw inputs, deeper layers refined from one forward sweep.
    fn bootstrap_inductive(&mut self, rt: &mut Runtime, split: Split) -> Result<()> {
        let ds = self.ds.clone();
        let nodes = ds.nodes_in_split(split);
        let f0 = ds.cfg.f_in_pad;
        // pass 1: raw features seed every layer's assignment
        for l in 0..self.vq.layers.len() {
            let fl = self.vq.layers[l].plan.f_in;
            let take = fl.min(f0);
            let mut rows = vec![0.0f32; nodes.len() * fl];
            for (i, &v) in nodes.iter().enumerate() {
                rows[i * fl..i * fl + take].copy_from_slice(
                    &ds.features[v as usize * f0..v as usize * f0 + take],
                );
            }
            self.assign_by_features(l, &nodes, &rows);
        }
        // pass 2: forward sweep yields true per-layer inputs; re-assign
        let art = self.infer_art.clone();
        let b = art.spec.b;
        let conv = self.conv_opt();
        let nl = self.vq.layers.len();
        let mut feats: Vec<Vec<f32>> = (0..nl)
            .map(|l| vec![0.0f32; nodes.len() * self.vq.layers[l].plan.f_in])
            .collect();
        let mut batch: Vec<u32> = Vec::with_capacity(b);
        let mut i = 0;
        while i < nodes.len() {
            let end = (i + b).min(nodes.len());
            batch.clear();
            batch.extend_from_slice(&nodes[i..end]);
            let real = batch.len();
            while batch.len() < b {
                batch.push(nodes[0]);
            }
            fill_session(
                &mut self.infer_io,
                &art.spec,
                &ds,
                &self.vq,
                &self.params,
                conv,
                &mut self.scratch,
                &mut self.rng,
                &mut self.pairs,
                &batch,
                0,
                false,
                None,
            )?;
            rt.execute_into(&art, &self.infer_io.inputs, &mut self.infer_io.outputs)?;
            for l in 0..nl {
                let fl = self.vq.layers[l].plan.f_in;
                let xi = self.infer_io.o_xfeat[l];
                feats[l][i * fl..end * fl]
                    .copy_from_slice(&self.infer_io.outputs[xi].f[..real * fl]);
            }
            i = end;
        }
        for l in 0..nl {
            let rows = std::mem::take(&mut feats[l]);
            self.assign_by_features(l, &nodes, &rows);
        }
        Ok(())
    }

    /// Feature-only nearest-codeword assignment for `nodes` (gradient
    /// columns masked out — unseen nodes have no gradient history).  Runs
    /// on the same blocked kernel as the in-graph FINDNEAREST.
    fn assign_by_features(&mut self, l: usize, nodes: &[u32], rows: &[f32]) {
        use crate::vq::kernels;
        let layer = &mut self.vq.layers[l];
        let (fl, fp) = (layer.plan.f_in, layer.plan.fp);
        let nb = layer.plan.n_br;
        debug_assert_eq!(rows.len(), nodes.len() * fl);
        let n_nodes = nodes.len();
        for j in 0..nb {
            let lo = j * fp;
            if lo >= fl {
                continue; // pure-gradient branch: keep previous assignment
            }
            let width = fp.min(fl - lo);
            let br = &layer.branches[j];
            // gather + whiten this branch's feature columns in one pass
            let inv = kernels::inv_std(&br.var[..width]);
            let mut vw = vec![0.0f32; n_nodes * width];
            for i in 0..n_nodes {
                for d in 0..width {
                    vw[i * width + d] = (rows[i * fl + lo + d] - br.mean[d]) * inv[d];
                }
            }
            let mut out = vec![0i32; n_nodes];
            kernels::assign_blocked(&vw, width, width, &br.cww, br.k, fp, &mut out);
            for (i, &node) in nodes.iter().enumerate() {
                layer.assign[j * layer.n + node as usize] = out[i] as u32;
            }
        }
    }
}
