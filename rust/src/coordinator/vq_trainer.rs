//! VQ-GNN trainer (paper Alg. 1): mini-batch sampling → sketch building →
//! one fused train-step execution (Eq. 6/7 + in-graph FINDNEAREST) →
//! RMSprop + VQ EMA update + assignment-table refresh.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::coordinator::opt::Optimizer;
use crate::coordinator::{gather_features, init_params, lipschitz_clip, opt, RunStats};
use crate::datasets::{Dataset, Split};
use crate::graph::Conv;
use crate::runtime::manifest::Manifest;
use crate::runtime::{Artifact, Runtime};
use crate::sampler::{NodeBatcher, NodeStrategy};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;
use crate::vq::sketch::{build_cnt_out, build_fixed, build_learnable, SketchScratch};
use crate::vq::VqModel;

/// Global gradient-scale cap for the learnable-convolution backbones.  In
/// practice attention gradients sit well above 1 every step (the decoupled
/// Eq. 7 messages are unnormalized), so this acts as gradient
/// *normalization* — each RMSprop step sees a unit-norm gradient direction,
/// which makes the update scale-free and immune to the occasional 1000×
/// Eq. 7 spike (verified over the exact training trajectories the
/// loss-descent tests assert).
const GRAD_NORM_CAP: f64 = 1.0;

/// L2 norm over the whole grad.* tail, accumulated in f64.
fn global_grad_norm(grads: &[Tensor]) -> f64 {
    grads
        .iter()
        .flat_map(|t| t.f.iter())
        .map(|&x| x as f64 * x as f64)
        .sum::<f64>()
        .sqrt()
}

/// Cap gradient-codeword rows at 10× the upper-median *nonzero* row L2 norm
/// before they enter the codebook EMA (App. E: the smoothed gradient
/// codewords are only meaningful if no single row dominates the cluster
/// statistics).  Zero rows — loss-masked validation/test/padding nodes,
/// which can be more than half the batch at the last layer — are excluded
/// from the median so they cannot collapse the cap onto the real rows.
fn winsorize_rows(gvec: &Tensor) -> Tensor {
    let (b, g) = (gvec.shape[0], gvec.shape[1]);
    let norms: Vec<f64> = (0..b)
        .map(|i| {
            gvec.f[i * g..(i + 1) * g]
                .iter()
                .map(|&x| x as f64 * x as f64)
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    let mut nonzero: Vec<f64> = norms.iter().copied().filter(|&n| n > 0.0).collect();
    if nonzero.is_empty() {
        return gvec.clone();
    }
    nonzero.sort_by(f64::total_cmp);
    let cap = 10.0 * nonzero[nonzero.len() / 2];
    let mut out = gvec.clone();
    for i in 0..b {
        if norms[i] > cap {
            let s = (cap / norms[i]) as f32;
            for x in out.f[i * g..(i + 1) * g].iter_mut() {
                *x *= s;
            }
        }
    }
    out
}

pub struct VqTrainer {
    pub train_art: Rc<Artifact>,
    pub infer_art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub vq: VqModel,
    pub params: Vec<Tensor>,
    opt: opt::RmsProp,
    batcher: NodeBatcher,
    scratch: SketchScratch,
    rng: Rng,
    gamma: f32,
    beta: f32,
    weight_clip: f32,
    p_pairs: usize,
    /// Per-layer (c_out, ct_out) stash between consecutive ctx inputs.
    pending: Option<(usize, Tensor, Tensor)>,
    pub stats: RunStats,
}

impl VqTrainer {
    /// `suffix` selects ablation artifacts ("", "_l2", "_k64", "_b256", ...).
    pub fn new(rt: &mut Runtime, man: &Manifest, ds: Rc<Dataset>,
               model_name: &str, suffix: &str, strategy: NodeStrategy,
               seed: u64) -> Result<VqTrainer> {
        let train_name = format!("vq_train_{}_{}{}", ds.cfg.name, model_name, suffix);
        let infer_name = format!("vq_infer_{}_{}{}", ds.cfg.name, model_name, suffix);
        let train_art = rt.load(man, &train_name)?;
        let infer_art = rt.load(man, &infer_name)?;
        let spec = &train_art.spec;
        let params = init_params(spec, seed);
        // Learnable convolutions step at lr/3: the Eq. 7 out-of-batch
        // gradient messages decouple raw attention scores from their own
        // denominators, so their early-training variance is higher than the
        // fixed convs' (bounded row-normalized coefficients) tolerate-ably
        // under the shared base lr.
        let lr = if matches!(model_name, "gat" | "txf") {
            man.train.lr / 3.0
        } else {
            man.train.lr
        };
        let opt = opt::RmsProp::new(lr as f32, man.train.rms_alpha as f32, &params);
        let vq = VqModel::init(&spec.plan, spec.k, ds.n(), seed);
        // transductive: batches over ALL nodes (loss masked to train nodes);
        // inductive: only training graphs' nodes are visible during training.
        let pool: Vec<u32> = if ds.cfg.inductive {
            ds.nodes_in_split(Split::Train)
        } else {
            (0..ds.n() as u32).collect()
        };
        let batcher = NodeBatcher::new(pool, spec.b, strategy);
        let scratch = SketchScratch::new(ds.n());
        Ok(VqTrainer {
            train_art,
            infer_art,
            model_name: model_name.to_string(),
            vq,
            params,
            opt,
            batcher,
            scratch,
            rng: Rng::new(seed ^ 0x7141),
            gamma: man.train.gamma as f32,
            beta: man.train.beta as f32,
            weight_clip: man.train.weight_clip as f32,
            p_pairs: man.train.p_pairs,
            pending: None,
            stats: RunStats::default(),
            ds,
        })
    }

    fn conv(&self) -> Conv {
        match self.model_name.as_str() {
            "gcn" => Conv::GcnSym,
            "sage" => Conv::SageMean,
            other => panic!("fixed conv requested for learnable model {other}"),
        }
    }

    fn learnable(&self) -> bool {
        matches!(self.model_name.as_str(), "gat" | "txf")
    }

    pub fn train_step(&mut self, rt: &mut Runtime) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let ds = self.ds.clone();
        let mut rng = self.rng.fork(self.stats.steps);
        let (batch, pad) = self.batcher.next_batch(&ds.graph, &mut rng);
        let art = self.train_art.clone();
        let inputs = self.assemble(&art, &batch, pad, true)?;
        let outputs = rt.execute(&art, &inputs)?;
        let spec = &art.spec;
        let loss = outputs[0].f[0];
        // VQ EMA updates + assignment-table refresh per layer (Alg. 2).
        // Learnable convolutions winsorize the gradient rows first: a
        // single spiky ∂ℓ/∂num row (attention-denominator conditioning)
        // would otherwise poison its cluster's EMA codeword for ~1/(1-γ)
        // steps and get re-broadcast into every later batch's Eq. 7
        // backward messages.
        for l in 0..spec.plan.len() {
            let xi = spec.output_index(&format!("l{l}.xfeat")).unwrap();
            let gi = spec.output_index(&format!("l{l}.gvec")).unwrap();
            let ai = spec.output_index(&format!("l{l}.assign")).unwrap();
            let gv;
            let gvec = if self.learnable() {
                gv = winsorize_rows(&outputs[gi]);
                &gv
            } else {
                &outputs[gi]
            };
            self.vq.layers[l].update_from_batch(
                &batch, &outputs[xi], gvec, &outputs[ai],
                self.gamma, self.beta,
            );
        }
        // optimizer on the grad.* tail (ordered like params); attention
        // backbones normalize the global gradient scale (GRAD_NORM_CAP) —
        // the same Eq. 7 spikes that motivate the winsorization also reach
        // the parameter gradients of the lower layers.
        let n_params = self.params.len();
        let tail = &outputs[outputs.len() - n_params..];
        let mut clipped: Option<Vec<Tensor>> = None;
        if self.learnable() {
            let norm = global_grad_norm(tail);
            if norm > GRAD_NORM_CAP {
                let s = (GRAD_NORM_CAP / norm) as f32;
                clipped = Some(
                    tail.iter()
                        .map(|t| {
                            Tensor::from_f32(&t.shape, t.f.iter().map(|x| x * s).collect())
                        })
                        .collect(),
                );
            }
        }
        let grads: Vec<&Tensor> = match &clipped {
            Some(v) => v.iter().collect(),
            None => tail.iter().collect(),
        };
        self.opt.step(&mut self.params, &grads);
        if self.learnable() {
            lipschitz_clip(spec, &mut self.params, self.weight_clip);
        }
        let step_bytes = spec.input_bytes() + spec.output_bytes()
            + opt::opt_state_bytes(&self.params, 1);
        self.stats.peak_step_bytes = self.stats.peak_step_bytes.max(step_bytes);
        self.stats.steps += 1;
        self.stats.loss_last = loss;
        self.stats.nodes_per_step = batch.len() as u64;
        self.stats.messages_per_step = self.count_messages(&batch);
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        Ok(loss)
    }

    /// Messages effectively preserved per step: ALL arcs into the batch
    /// (paper Fig. 1 — intra-batch exact + codeword-merged).
    fn count_messages(&self, batch: &[u32]) -> u64 {
        batch
            .iter()
            .map(|&v| self.ds.graph.in_degree(v as usize) as u64 + 1)
            .sum()
    }

    pub fn epoch(&mut self, rt: &mut Runtime) -> Result<f32> {
        let mut last = 0.0;
        for _ in 0..self.batcher.batches_per_epoch() {
            last = self.train_step(rt)?;
        }
        Ok(last)
    }

    /// Mini-batch inference over arbitrary nodes via the infer artifact;
    /// returns row-major (|nodes|, c) logits/embeddings.
    pub fn infer_nodes(&mut self, rt: &mut Runtime, nodes: &[u32]) -> Result<Vec<f32>> {
        let art = self.infer_art.clone();
        let b = art.spec.b;
        let c = art.spec.outputs[0].shape[1];
        let mut logits = vec![0.0f32; nodes.len() * c];
        let mut i = 0;
        while i < nodes.len() {
            let end = (i + b).min(nodes.len());
            let mut batch: Vec<u32> = nodes[i..end].to_vec();
            let real = batch.len();
            while batch.len() < b {
                batch.push(nodes[0]); // pad rows; outputs ignored
            }
            let inputs = self.assemble(&art, &batch, 0, false)?;
            let out = rt.execute(&art, &inputs)?;
            logits[i * c..end * c].copy_from_slice(&out[0].f[..real * c]);
            i = end;
        }
        Ok(logits)
    }

    /// Evaluate the task metric on a split (accuracy / micro-F1 / Hits@50).
    pub fn evaluate(&mut self, rt: &mut Runtime, split: Split) -> Result<f64> {
        use crate::coordinator::metrics;
        let ds = self.ds.clone();
        if ds.cfg.task == "link" {
            return self.evaluate_link(rt, split);
        }
        if ds.cfg.inductive && split != Split::Train {
            self.bootstrap_inductive(rt, split)?;
        }
        let nodes = ds.nodes_in_split(split);
        let logits = self.infer_nodes(rt, &nodes)?;
        let rows: Vec<usize> = (0..nodes.len()).collect();
        let c = ds.cfg.n_classes;
        if ds.cfg.multilabel {
            let mut tgt = vec![0.0f32; nodes.len() * c];
            for (i, &v) in nodes.iter().enumerate() {
                tgt[i * c..(i + 1) * c].copy_from_slice(
                    &ds.labels_multi[v as usize * c..(v as usize + 1) * c],
                );
            }
            Ok(metrics::micro_f1(&logits, c, &tgt, &rows))
        } else {
            let labels: Vec<i32> = nodes.iter().map(|&v| ds.labels[v as usize]).collect();
            Ok(metrics::accuracy(&logits, c, &labels, &rows))
        }
    }

    fn evaluate_link(&mut self, rt: &mut Runtime, split: Split) -> Result<f64> {
        use crate::coordinator::metrics;
        let ds = self.ds.clone();
        let all: Vec<u32> = (0..ds.n() as u32).collect();
        let h = self.infer_art.spec.outputs[0].shape[1];
        let emb = self.infer_nodes(rt, &all)?;
        let score = |u: u32, v: u32| -> f32 {
            emb[u as usize * h..(u as usize + 1) * h]
                .iter()
                .zip(&emb[v as usize * h..(v as usize + 1) * h])
                .map(|(x, y)| x * y)
                .sum()
        };
        let pos = if split == Split::Val { &ds.val_pos } else { &ds.test_pos };
        let pos_scores: Vec<f32> = pos.iter().map(|&(u, v)| score(u, v)).collect();
        let mut rng = Rng::new(0xBEEF);
        let neg_scores: Vec<f32> = (0..4096)
            .map(|_| score(rng.below(ds.n()) as u32, rng.below(ds.n()) as u32))
            .collect();
        Ok(metrics::hits_at_k(&pos_scores, &neg_scores, 50))
    }

    /// Inductive inference bootstrap (paper §6 "one extra step"): assign
    /// unseen nodes to their nearest codewords by *feature* columns — layer
    /// 0 from raw inputs, deeper layers refined from one forward sweep.
    fn bootstrap_inductive(&mut self, rt: &mut Runtime, split: Split) -> Result<()> {
        let ds = self.ds.clone();
        let nodes = ds.nodes_in_split(split);
        let f0 = ds.cfg.f_in_pad;
        // pass 1: raw features seed every layer's assignment
        for l in 0..self.vq.layers.len() {
            let fl = self.vq.layers[l].plan.f_in;
            let take = fl.min(f0);
            let mut rows = vec![0.0f32; nodes.len() * fl];
            for (i, &v) in nodes.iter().enumerate() {
                rows[i * fl..i * fl + take].copy_from_slice(
                    &ds.features[v as usize * f0..v as usize * f0 + take],
                );
            }
            self.assign_by_features(l, &nodes, &rows);
        }
        // pass 2: forward sweep yields true per-layer inputs; re-assign
        let art = self.infer_art.clone();
        let spec = art.spec.clone();
        let b = spec.b;
        let nl = self.vq.layers.len();
        let mut feats: Vec<Vec<f32>> = (0..nl)
            .map(|l| vec![0.0f32; nodes.len() * self.vq.layers[l].plan.f_in])
            .collect();
        let mut i = 0;
        while i < nodes.len() {
            let end = (i + b).min(nodes.len());
            let mut batch: Vec<u32> = nodes[i..end].to_vec();
            let real = batch.len();
            while batch.len() < b {
                batch.push(nodes[0]);
            }
            let inputs = self.assemble(&art, &batch, 0, false)?;
            let out = rt.execute(&art, &inputs)?;
            for l in 0..nl {
                let fl = self.vq.layers[l].plan.f_in;
                let xi = spec.output_index(&format!("l{l}.xfeat")).unwrap();
                feats[l][i * fl..end * fl].copy_from_slice(&out[xi].f[..real * fl]);
            }
            i = end;
        }
        for l in 0..nl {
            let rows = std::mem::take(&mut feats[l]);
            self.assign_by_features(l, &nodes, &rows);
        }
        Ok(())
    }

    /// Feature-only nearest-codeword assignment for `nodes` (gradient
    /// columns masked out — unseen nodes have no gradient history).  Runs
    /// on the same blocked kernel as the in-graph FINDNEAREST.
    fn assign_by_features(&mut self, l: usize, nodes: &[u32], rows: &[f32]) {
        use crate::vq::kernels;
        let layer = &mut self.vq.layers[l];
        let (fl, fp) = (layer.plan.f_in, layer.plan.fp);
        let nb = layer.plan.n_br;
        debug_assert_eq!(rows.len(), nodes.len() * fl);
        let n_nodes = nodes.len();
        for j in 0..nb {
            let lo = j * fp;
            if lo >= fl {
                continue; // pure-gradient branch: keep previous assignment
            }
            let width = fp.min(fl - lo);
            let br = &layer.branches[j];
            // gather + whiten this branch's feature columns in one pass
            let inv = kernels::inv_std(&br.var[..width]);
            let mut vw = vec![0.0f32; n_nodes * width];
            for i in 0..n_nodes {
                for d in 0..width {
                    vw[i * width + d] = (rows[i * fl + lo + d] - br.mean[d]) * inv[d];
                }
            }
            let mut out = vec![0i32; n_nodes];
            kernels::assign_blocked(&vw, width, width, &br.cww, br.k, fp, &mut out);
            for (i, &node) in nodes.iter().enumerate() {
                layer.assign[j * layer.n + node as usize] = out[i] as u32;
            }
        }
    }

    /// Sample link-prediction training pairs: positives are intra-batch
    /// arcs, negatives random intra-batch pairs; padding pairs get weight 0.
    fn fill_link_pairs(&mut self, spec_p: usize, batch: &[u32], train: bool)
                       -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>) {
        let p = spec_p;
        let b = batch.len();
        let mut pos = Vec::new();
        if train {
            let mut local = std::collections::HashMap::new();
            for (i, &g) in batch.iter().enumerate() {
                local.insert(g, i as i32);
            }
            'outer: for (i, &g) in batch.iter().enumerate() {
                for &u in self.ds.graph.in_neighbors(g as usize) {
                    if let Some(&lu) = local.get(&u) {
                        pos.push((lu, i as i32));
                        if pos.len() >= p / 2 {
                            break 'outer;
                        }
                    }
                }
            }
        }
        let mut psrc = vec![0i32; p];
        let mut pdst = vec![0i32; p];
        let mut py = vec![0.0f32; p];
        let mut pw = vec![0.0f32; p];
        for (i, &(u, v)) in pos.iter().enumerate() {
            psrc[i] = u;
            pdst[i] = v;
            py[i] = 1.0;
            pw[i] = 1.0;
        }
        for i in pos.len()..p {
            psrc[i] = self.rng.below(b) as i32;
            pdst[i] = self.rng.below(b) as i32;
            pw[i] = if train { 1.0 } else { 0.0 };
        }
        (psrc, pdst, py, pw)
    }

    /// Assemble the artifact's ordered input list for one batch.
    fn assemble(&mut self, art: &Rc<Artifact>, batch: &[u32], pad: usize,
                train: bool) -> Result<Vec<Tensor>> {
        self.pending = None;
        let spec = &art.spec;
        let ds = self.ds.clone();
        let b = batch.len();
        let f = ds.cfg.f_in_pad;
        let link_pairs = if ds.cfg.task == "link" && spec.input_index("psrc").is_some() {
            Some(self.fill_link_pairs(
                spec.inputs[spec.input_index("psrc").unwrap()].numel(),
                batch, train,
            ))
        } else {
            None
        };
        let mut inputs: Vec<Tensor> = Vec::with_capacity(spec.inputs.len());
        let mut pi = 0usize;
        for ts in &spec.inputs {
            let name = ts.name.as_str();
            let t: Tensor = if name == "xb" {
                gather_features(&ds.features, f, batch)
            } else if name == "y" {
                if ds.cfg.multilabel {
                    let c = ds.cfg.n_classes;
                    let mut data = Vec::with_capacity(b * c);
                    for &v in batch {
                        data.extend_from_slice(
                            &ds.labels_multi[v as usize * c..(v as usize + 1) * c],
                        );
                    }
                    Tensor::from_f32(&[b, c], data)
                } else {
                    Tensor::from_i32(
                        &[b],
                        batch.iter().map(|&v| ds.labels[v as usize]).collect(),
                    )
                }
            } else if name == "wloss" {
                let mut w: Vec<f32> = batch
                    .iter()
                    .map(|&v| {
                        if train && ds.split[v as usize] != Split::Train {
                            0.0
                        } else {
                            1.0
                        }
                    })
                    .collect();
                for i in (b - pad)..b {
                    w[i] = 0.0;
                }
                Tensor::from_f32(&[b], w)
            } else if name == "psrc" {
                Tensor::from_i32(&ts.shape, link_pairs.as_ref().unwrap().0.clone())
            } else if name == "pdst" {
                Tensor::from_i32(&ts.shape, link_pairs.as_ref().unwrap().1.clone())
            } else if name == "py" {
                Tensor::from_f32(&ts.shape, link_pairs.as_ref().unwrap().2.clone())
            } else if name == "pw" {
                Tensor::from_f32(&ts.shape, link_pairs.as_ref().unwrap().3.clone())
            } else if name.starts_with("param.") {
                let t = self.params[pi].clone();
                pi += 1;
                t
            } else if let Some((lstr, field)) = name.split_once('.') {
                let l: usize = lstr[1..].parse().context("layer index")?;
                match field {
                    "c_in" => {
                        let layer = &self.vq.layers[l];
                        let (c_in, c_out, ct_out) = build_fixed(
                            &ds.graph, self.conv(), batch, layer, &mut self.scratch,
                        );
                        self.pending = Some((l, c_out, ct_out));
                        c_in
                    }
                    "c_out" => {
                        let (pl, c_out, _) = self.pending.as_ref().unwrap();
                        assert_eq!(*pl, l);
                        c_out.clone()
                    }
                    "ct_out" => {
                        let (pl, _, ct_out) = self.pending.take().unwrap();
                        assert_eq!(pl, l);
                        ct_out
                    }
                    "mask_in" => {
                        let layer = &self.vq.layers[l];
                        let (mask_in, m_out, m_out_t) = build_learnable(
                            &ds.graph, batch, layer, &mut self.scratch,
                        );
                        self.pending = Some((l, m_out, m_out_t));
                        mask_in
                    }
                    "m_out" => {
                        let (pl, m_out, _) = self.pending.as_ref().unwrap();
                        assert_eq!(*pl, l);
                        m_out.clone()
                    }
                    "m_out_t" => {
                        let (pl, _, m_out_t) = self.pending.take().unwrap();
                        assert_eq!(pl, l);
                        m_out_t
                    }
                    "cnt_out" => build_cnt_out(batch, &self.vq.layers[l], &mut self.scratch),
                    "cw" => self.vq.layers[l].cw_tensor(),
                    "cww" => self.vq.layers[l].cww_tensor(),
                    "mean" => self.vq.layers[l].mean_tensor(),
                    "var" => self.vq.layers[l].var_tensor(),
                    other => anyhow::bail!("unknown ctx field {other}"),
                }
            } else {
                anyhow::bail!("unknown input {name}")
            };
            inputs.push(t);
        }
        Ok(inputs)
    }
}
