//! Graph substrate: CSR storage (both directions), Table-1 convolution
//! normalizations, subgraph induction, and edge-list export for the AOT
//! artifacts.

use crate::util::rng::Rng;

/// Undirected graphs are stored as two directed arcs.  `Csr` holds both the
/// outgoing adjacency (src → dst, used by transposed-convolution sketches)
/// and the incoming adjacency (receiver-major, used by message passing).
#[derive(Debug, Clone)]
pub struct Graph {
    pub n: usize,
    /// Outgoing CSR: out_ptr[u]..out_ptr[u+1] indexes out_col (targets of u).
    pub out_ptr: Vec<u32>,
    pub out_col: Vec<u32>,
    /// Incoming CSR: in_ptr[v]..in_ptr[v+1] indexes in_col (sources into v).
    pub in_ptr: Vec<u32>,
    pub in_col: Vec<u32>,
    /// Component id per node (disjoint-union datasets like ppi_sim).
    pub component: Vec<u32>,
}

/// Which Table-1 convolution matrix a coefficient array realizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Conv {
    /// GCN: C = D̃^{-1/2} Ã D̃^{-1/2} (self loops included).
    GcnSym,
    /// SAGE-Mean aggregator: C = D^{-1} A (no self loops; identity support
    /// is handled separately inside the model).
    SageMean,
}

impl Conv {
    pub fn with_self_loops(self) -> bool {
        matches!(self, Conv::GcnSym)
    }
}

impl Graph {
    /// Build from undirected edge pairs (u, v); deduped, self loops dropped
    /// (the convolutions re-add them as needed).
    pub fn from_undirected(n: usize, edges: &[(u32, u32)]) -> Graph {
        let mut arcs: Vec<(u32, u32)> = Vec::with_capacity(edges.len() * 2);
        for &(u, v) in edges {
            if u == v || u as usize >= n || v as usize >= n {
                continue;
            }
            arcs.push((u, v));
            arcs.push((v, u));
        }
        arcs.sort_unstable();
        arcs.dedup();
        Self::from_arcs(n, &arcs)
    }

    /// Build from directed arcs (already deduped & in-range).
    pub fn from_arcs(n: usize, arcs: &[(u32, u32)]) -> Graph {
        let mut out_ptr = vec![0u32; n + 1];
        for &(u, _) in arcs {
            out_ptr[u as usize + 1] += 1;
        }
        for i in 0..n {
            out_ptr[i + 1] += out_ptr[i];
        }
        let mut out_col = vec![0u32; arcs.len()];
        let mut cur = out_ptr.clone();
        for &(u, v) in arcs {
            out_col[cur[u as usize] as usize] = v;
            cur[u as usize] += 1;
        }
        // incoming = transpose
        let mut in_ptr = vec![0u32; n + 1];
        for &(_, v) in arcs {
            in_ptr[v as usize + 1] += 1;
        }
        for i in 0..n {
            in_ptr[i + 1] += in_ptr[i];
        }
        let mut in_col = vec![0u32; arcs.len()];
        let mut cur = in_ptr.clone();
        for &(u, v) in arcs {
            in_col[cur[v as usize] as usize] = u;
            cur[v as usize] += 1;
        }
        Graph { n, out_ptr, out_col, in_ptr, in_col, component: vec![0; n] }
    }

    pub fn num_arcs(&self) -> usize {
        self.out_col.len()
    }

    pub fn avg_degree(&self) -> f64 {
        self.num_arcs() as f64 / self.n.max(1) as f64
    }

    pub fn out_neighbors(&self, u: usize) -> &[u32] {
        &self.out_col[self.out_ptr[u] as usize..self.out_ptr[u + 1] as usize]
    }

    pub fn in_neighbors(&self, v: usize) -> &[u32] {
        &self.in_col[self.in_ptr[v] as usize..self.in_ptr[v + 1] as usize]
    }

    pub fn in_degree(&self, v: usize) -> usize {
        (self.in_ptr[v + 1] - self.in_ptr[v]) as usize
    }

    pub fn out_degree(&self, u: usize) -> usize {
        (self.out_ptr[u + 1] - self.out_ptr[u]) as usize
    }

    /// Convolution coefficient of the arc (src → dst) under `conv`.
    /// (Self-loop coefficients are queried with src == dst.)
    pub fn coef(&self, conv: Conv, src: usize, dst: usize) -> f32 {
        match conv {
            Conv::GcnSym => {
                let dd = (self.in_degree(dst) + 1) as f32;
                let ds = (self.in_degree(src) + 1) as f32;
                1.0 / (dd * ds).sqrt()
            }
            Conv::SageMean => {
                let d = self.in_degree(dst);
                if d == 0 {
                    0.0
                } else {
                    1.0 / d as f32
                }
            }
        }
    }

    /// Export the full graph as a padded directed edge list for the edge
    /// artifacts: (esrc, edst, ecoef), including self loops when the
    /// convolution asks for them.  Padding arcs have coef 0 and src=dst=0.
    pub fn edge_list(&self, conv: Conv, capacity: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let with_loops = conv.with_self_loops();
        let want = self.num_arcs() + if with_loops { self.n } else { 0 };
        assert!(want <= capacity, "edge list {want} exceeds capacity {capacity}");
        let mut esrc = Vec::with_capacity(capacity);
        let mut edst = Vec::with_capacity(capacity);
        let mut coef = Vec::with_capacity(capacity);
        for v in 0..self.n {
            for &u in self.in_neighbors(v) {
                esrc.push(u as i32);
                edst.push(v as i32);
                coef.push(self.coef(conv, u as usize, v));
            }
            if with_loops {
                esrc.push(v as i32);
                edst.push(v as i32);
                coef.push(self.coef(conv, v, v));
            }
        }
        esrc.resize(capacity, 0);
        edst.resize(capacity, 0);
        coef.resize(capacity, 0.0);
        (esrc, edst, coef)
    }

    /// Induced subgraph on `nodes`; returns local edge list (src, dst) in
    /// local indices, self loops excluded.  O(Σ deg(nodes)).
    pub fn induced_edges(&self, nodes: &[u32], local: &mut [i32]) -> Vec<(u32, u32)> {
        // local: scratch of size n filled with -1 (caller reuses it).
        for (li, &g) in nodes.iter().enumerate() {
            local[g as usize] = li as i32;
        }
        let mut edges = Vec::new();
        for (li, &g) in nodes.iter().enumerate() {
            for &u in self.in_neighbors(g as usize) {
                let lu = local[u as usize];
                if lu >= 0 {
                    edges.push((lu as u32, li as u32));
                }
            }
        }
        for &g in nodes {
            local[g as usize] = -1;
        }
        edges
    }

    /// Random walk of `len` steps from `start` (undirected graphs: uses
    /// outgoing arcs).  Stays in place at dead ends.
    pub fn random_walk(&self, start: u32, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(len + 1);
        let mut cur = start;
        out.push(cur);
        for _ in 0..len {
            let nb = self.out_neighbors(cur as usize);
            if nb.is_empty() {
                break;
            }
            cur = nb[rng.below(nb.len())];
            out.push(cur);
        }
        out
    }

    /// Connected components (on the undirected structure).
    pub fn compute_components(&mut self) {
        let mut comp = vec![u32::MAX; self.n];
        let mut next = 0u32;
        let mut stack = Vec::new();
        for s in 0..self.n {
            if comp[s] != u32::MAX {
                continue;
            }
            comp[s] = next;
            stack.push(s as u32);
            while let Some(u) = stack.pop() {
                for &v in self.out_neighbors(u as usize) {
                    if comp[v as usize] == u32::MAX {
                        comp[v as usize] = next;
                        stack.push(v);
                    }
                }
                for &v in self.in_neighbors(u as usize) {
                    if comp[v as usize] == u32::MAX {
                        comp[v as usize] = next;
                        stack.push(v);
                    }
                }
            }
            next += 1;
        }
        self.component = comp;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        // 0 - 1 - 2
        Graph::from_undirected(3, &[(0, 1), (1, 2)])
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = path3();
        assert_eq!(g.num_arcs(), 4);
        assert_eq!(g.in_degree(1), 2);
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.in_neighbors(1), &[0, 2]);
    }

    #[test]
    fn dedup_and_self_loop_drop() {
        let g = Graph::from_undirected(3, &[(0, 1), (1, 0), (2, 2), (0, 1)]);
        assert_eq!(g.num_arcs(), 2);
    }

    #[test]
    fn gcn_coef_symmetry_and_rowsum() {
        let g = path3();
        // C = D̃^{-1/2} Ã D̃^{-1/2}: symmetric
        let c01 = g.coef(Conv::GcnSym, 0, 1);
        let c10 = g.coef(Conv::GcnSym, 1, 0);
        assert!((c01 - c10).abs() < 1e-6);
        // deg̃(0)=2, deg̃(1)=3 → c = 1/sqrt(6)
        assert!((c01 - 1.0 / 6.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn sage_coef_is_mean() {
        let g = path3();
        assert!((g.coef(Conv::SageMean, 0, 1) - 0.5).abs() < 1e-6);
        assert!((g.coef(Conv::SageMean, 1, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn edge_list_padded_with_self_loops() {
        let g = path3();
        let (es, ed, c) = g.edge_list(Conv::GcnSym, 16);
        assert_eq!(es.len(), 16);
        let n_real = c.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(n_real, 4 + 3); // arcs + self loops
        // self loop of node 1: 1/deg̃(1) = 1/3
        let idx = (0..16).find(|&i| es[i] == 1 && ed[i] == 1).unwrap();
        assert!((c[idx] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn induced_subgraph() {
        let g = Graph::from_undirected(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let mut scratch = vec![-1i32; 5];
        let e = g.induced_edges(&[1, 2, 4], &mut scratch);
        // only 1-2 survives (both directions)
        assert_eq!(e.len(), 2);
        assert!(scratch.iter().all(|&x| x == -1));
    }

    #[test]
    fn components() {
        let mut g = Graph::from_undirected(5, &[(0, 1), (2, 3)]);
        g.compute_components();
        assert_eq!(g.component[0], g.component[1]);
        assert_eq!(g.component[2], g.component[3]);
        assert_ne!(g.component[0], g.component[2]);
        assert_ne!(g.component[4], g.component[0]);
    }

    #[test]
    fn random_walk_stays_connected() {
        let g = Graph::from_undirected(6, &[(0, 1), (1, 2), (2, 0), (3, 4)]);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let w = g.random_walk(0, 8, &mut rng);
            assert!(w.iter().all(|&x| x < 3), "{w:?}");
        }
    }
}
