//! Native-backend correctness: kernel parity against the scalar reference
//! semantics (python/compile/kernels/ref.py + compile/vq.py), golden replay
//! of the interpreted train step against a spec-verified transcription (all
//! four backbones + the edge paths), and deterministic loss-descent runs —
//! all with no Python, no JAX and no `artifacts/` directory.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (the CI backbone
//! matrix runs one backbone per leg).

#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

mod common;

use std::rc::Rc;

use common::{builtin, golden_inputs, model_enabled};
use vq_gnn::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::util::rng::Rng;
use vq_gnn::util::tensor::Tensor;
use vq_gnn::vq::{VqBranch, EPS};

// ---------------------------------------------------------------------------
// Kernel parity
// ---------------------------------------------------------------------------

/// Transcription of python/compile/vq.py::vq_update (the executable spec).
struct RefState {
    cww: Vec<f32>,
    counts: Vec<f32>,
    sums: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
}

fn ref_update(st: &mut RefState, v: &[f32], assign: &[i32], k: usize, fp: usize,
              gamma: f32, beta: f32) {
    let b = assign.len();
    for d in 0..fp {
        let mut m = 0.0f64;
        for i in 0..b {
            m += v[i * fp + d] as f64;
        }
        let m = (m / b as f64) as f32;
        let mut va = 0.0f64;
        for i in 0..b {
            let x = (v[i * fp + d] - m) as f64;
            va += x * x;
        }
        let va = (va / b as f64) as f32;
        st.mean[d] = st.mean[d] * beta + m * (1.0 - beta);
        st.var[d] = st.var[d] * beta + va * (1.0 - beta);
    }
    for c in st.counts.iter_mut() {
        *c *= gamma;
    }
    for s in st.sums.iter_mut() {
        *s *= gamma;
    }
    let g1 = 1.0 - gamma;
    for i in 0..b {
        let a = assign[i] as usize;
        st.counts[a] += g1;
        for d in 0..fp {
            let w = (v[i * fp + d] - st.mean[d]) / (st.var[d] + EPS).sqrt();
            st.sums[a * fp + d] += g1 * w;
        }
    }
    for c in 0..k {
        if st.counts[c] > 1e-6 {
            for d in 0..fp {
                st.cww[c * fp + d] = st.sums[c * fp + d] / st.counts[c];
            }
        }
    }
}

#[test]
fn update_matches_reference_semantics_randomized() {
    // Property (replacing the old fixed-shape parity test): for randomized
    // (b, k, fp) — including b below the parallel ROW_BLOCK and k = 1 — one
    // Alg. 2 update from identical pre-state matches the scalar reference
    // transcription of compile/vq.py within 1e-5 relative, on every piece
    // of state, across a few consecutive rounds.
    vq_gnn::util::prop::check("vq_update_parity", 20, |rng, _case| {
        let b = 1 + rng.below(3 * vq_gnn::vq::kernels::ROW_BLOCK);
        let k = 1 + rng.below(32);
        let fp = 1 + rng.below(16);
        let mut br = VqBranch::init(k, fp, rng);
        for round in 0..3 {
            let mut st = RefState {
                cww: br.cww.clone(),
                counts: br.counts.clone(),
                sums: br.sums.clone(),
                mean: br.mean.clone(),
                var: br.var.clone(),
            };
            let v: Vec<f32> = (0..b * fp).map(|_| 1.5 * rng.gauss_f32() + 0.3).collect();
            let assign = br.assign_host(&v);
            br.update(&v, &assign, 0.97, 0.95);
            ref_update(&mut st, &v, &assign, k, fp, 0.97, 0.95);
            for (what, got, want) in [
                ("mean", &br.mean, &st.mean),
                ("var", &br.var, &st.var),
                ("counts", &br.counts, &st.counts),
                ("sums", &br.sums, &st.sums),
                ("cww", &br.cww, &st.cww),
            ] {
                for (i, (x, y)) in got.iter().zip(want.iter()).enumerate() {
                    if (x - y).abs() >= 1e-5 * y.abs().max(1.0) {
                        return Err(format!(
                            "b={b} k={k} fp={fp} round {round}: {what}[{i}] {x} vs {y}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn assignment_ties_break_identically_to_reference() {
    // Duplicate codewords are bit-identical under the decomposed distance,
    // so the blocked kernel must return the lowest index — same rule as the
    // scalar reference loop and jnp.argmin.
    let mut rng = Rng::new(22);
    let (k, fp) = (12usize, 6usize);
    let mut br = VqBranch::init(k, fp, &mut rng);
    for c in (0..k).step_by(3) {
        // make codewords {c, c+1, c+2} identical
        let proto: Vec<f32> = br.cww[c * fp..(c + 1) * fp].to_vec();
        for dup in 1..3 {
            br.cww[(c + dup) * fp..(c + dup + 1) * fp].copy_from_slice(&proto);
        }
    }
    let v: Vec<f32> = (0..64 * fp).map(|_| rng.gauss_f32()).collect();
    let got = br.assign_host(&v);
    for &a in &got {
        assert_eq!(a % 3, 0, "tie broken away from the lowest duplicate index");
    }
}

// ---------------------------------------------------------------------------
// Native interpreter: golden replay against the executable python spec
// ---------------------------------------------------------------------------
//
// Inputs are generated from a fixed SplitMix64 stream with per-name rules
// (tests/common/mod.rs); the expected per-output |·|-sums were produced by
// an f64 transcription of the artifact semantics.  For gcn/sage the
// transcription was verified exactly against torch autograd; for gat/txf
// and the edge paths every output (including the Eq. 7 custom-VJP codeword
// term and all attention-parameter gradients) was verified elementwise
// against the repo's own JAX executable spec (python/compile/model.py /
// edgemp.py run under jax.value_and_grad) to f32 rounding (~5e-7 rel L2).

fn abs_sum(t: &Tensor) -> f64 {
    t.f.iter().map(|&x| x.abs() as f64).sum()
}

fn check_golden(man: &Manifest, artifact: &str, expect: &[(&str, f64)]) {
    let mut rt = Runtime::native();
    let art = rt.load(man, artifact).unwrap();
    let spec = art.spec.clone();
    let mut rng = Rng::new(1234);
    let inputs = golden_inputs(man, artifact, &mut rng);
    let outputs = rt.execute(&art, &inputs).unwrap();
    for &(name, want) in expect {
        let idx = spec.output_index(name).unwrap_or_else(|| panic!("{name}?"));
        let got = abs_sum(&outputs[idx]);
        let rel = (got - want).abs() / want.abs().max(1e-9);
        assert!(rel < 2e-3, "{artifact}/{name}: |sum| {got:.6e} vs golden {want:.6e}");
    }
    // Assignments: recompute with an independent scalar loop from the
    // artifact's own xfeat/gvec outputs + the whitening inputs (this pins
    // the concat layout and the per-branch mean/var/cww slicing).
    for (l, p) in spec.plan.iter().enumerate() {
        let ai = match spec.output_index(&format!("l{l}.assign")) {
            Some(i) => i,
            None => continue,
        };
        let xf = &outputs[spec.output_index(&format!("l{l}.xfeat")).unwrap()].f;
        let gv = &outputs[spec.output_index(&format!("l{l}.gvec")).unwrap()].f;
        let mean = &inputs[spec.input_index(&format!("l{l}.mean")).unwrap()].f;
        let var = &inputs[spec.input_index(&format!("l{l}.var")).unwrap()].f;
        let cww = &inputs[spec.input_index(&format!("l{l}.cww")).unwrap()].f;
        let b = spec.b;
        let k = spec.k;
        for j in 0..p.n_br {
            for i in 0..b {
                let mut best = f32::INFINITY;
                let mut arg = 0usize;
                for c in 0..k {
                    let mut d2 = 0.0f32;
                    for d in 0..p.fp {
                        let col = j * p.fp + d;
                        let raw = if col < p.f_in {
                            xf[i * p.f_in + col]
                        } else if col < p.f_in + p.g_dim {
                            gv[i * p.g_dim + (col - p.f_in)]
                        } else {
                            0.0
                        };
                        let w = (raw - mean[j * p.fp + d])
                            / (var[j * p.fp + d] + EPS).sqrt();
                        let diff = w - cww[(j * k + c) * p.fp + d];
                        d2 += diff * diff;
                    }
                    if d2 < best {
                        best = d2;
                        arg = c;
                    }
                }
                assert_eq!(
                    outputs[ai].i[j * b + i],
                    arg as i32,
                    "{artifact}: l{l}.assign[{j},{i}]"
                );
            }
        }
    }
}

#[test]
fn native_vq_train_gcn_matches_golden() {
    if !model_enabled("gcn") {
        return;
    }
    check_golden(
        &builtin(),
        "vq_train_tiny_sim_gcn",
        &[
            ("loss", 3.082491),
            ("logits", 536.4595),
            ("l0.xfeat", 248.8563),
            ("l0.gvec", 827.5031),
            ("l1.xfeat", 986.0641),
            ("l1.gvec", 172.6918),
            ("l2.xfeat", 2143.193),
            ("l2.gvec", 1.473805),
            ("grad.l2.bias", 0.1122031),
            ("grad.l2.w", 23.83987),
            ("grad.l1.bias", 118.8183),
            ("grad.l1.w", 1329.709),
            ("grad.l0.bias", 323.8965),
            ("grad.l0.w", 937.2725),
        ],
    );
}

#[test]
fn native_vq_train_sage_matches_golden() {
    if !model_enabled("sage") {
        return;
    }
    check_golden(
        &builtin(),
        "vq_train_tiny_sim_sage",
        &[
            ("loss", 4.008024),
            ("logits", 937.6693),
            ("l0.xfeat", 248.8563),
            ("l0.gvec", 899.6932),
            ("l1.xfeat", 1181.597),
            ("l1.gvec", 185.7798),
            ("l2.xfeat", 3295.760),
            ("l2.gvec", 1.428242),
            ("grad.l2.bias", 0.2539292),
            ("grad.l2.w_self", 17.85627),
            ("grad.l2.w_nbr", 26.73761),
            ("grad.l1.bias", 129.1591),
            ("grad.l1.w_self", 2435.897),
            ("grad.l1.w_nbr", 1441.417),
            ("grad.l0.bias", 392.1026),
            ("grad.l0.w_self", 730.9437),
            ("grad.l0.w_nbr", 1031.248),
        ],
    );
}

#[test]
fn native_edge_train_matches_golden() {
    if !model_enabled("gcn") {
        return;
    }
    check_golden(
        &builtin(),
        "edge_train_tiny_sim_gcn_full",
        &[
            ("loss", 4.358341),
            ("logits", 4522.803),
            ("grad.l2.bias", 0.5461148),
            ("grad.l2.w", 70.84764),
            ("grad.l1.bias", 6.107460),
            ("grad.l1.w", 208.8501),
            ("grad.l0.bias", 22.58445),
            ("grad.l0.w", 31.06524),
        ],
    );
}

#[test]
fn native_vq_train_gat_matches_golden() {
    if !model_enabled("gat") {
        return;
    }
    check_golden(
        &builtin(),
        "vq_train_tiny_sim_gat",
        &[
            ("loss", 1.432787),
            ("logits", 82.09287),
            ("l0.xfeat", 248.8563),
            ("l0.gvec", 804.4376),
            ("l1.xfeat", 639.2114),
            ("l1.gvec", 55.7517),
            ("l2.xfeat", 861.2833),
            ("l2.gvec", 0.06601402),
            ("grad.l0.w", 16397.14),
            ("grad.l0.a_src", 2032.71),
            ("grad.l0.a_dst", 516.1588),
            ("grad.l0.bias", 13268.66),
            ("grad.l1.w", 2819.003),
            ("grad.l1.a_src", 173.4468),
            ("grad.l1.a_dst", 26.88899),
            ("grad.l1.bias", 307.8307),
            ("grad.l2.w", 2.755629),
            ("grad.l2.a_src", 0.07505542),
            ("grad.l2.a_dst", 0.03376212),
            ("grad.l2.bias", 0.3237223),
        ],
    );
}

#[test]
fn native_vq_train_txf_matches_golden() {
    if !model_enabled("txf") {
        return;
    }
    check_golden(
        &builtin(),
        "vq_train_tiny_sim_txf",
        &[
            ("loss", 1.902687),
            ("logits", 294.9183),
            ("l0.xfeat", 248.8563),
            ("l0.gvec", 4915.819),
            ("l1.xfeat", 725.4117),
            ("l1.gvec", 2929.882),
            ("l2.xfeat", 1372.478),
            ("l2.gvec", 0.06715583),
            ("grad.l0.w", 56212.44),
            ("grad.l0.a_src", 11263.27),
            ("grad.l0.a_dst", 1772.617),
            ("grad.l0.bias", 97161.53),
            ("grad.l0.wq", 4576.105),
            ("grad.l0.wk", 4586.834),
            ("grad.l0.wv", 54429.16),
            ("grad.l0.w_lin", 119843.0),
            ("grad.l1.w", 307806.6),
            ("grad.l1.a_src", 13214.57),
            ("grad.l1.a_dst", 6412.104),
            ("grad.l1.bias", 38555.04),
            ("grad.l1.wq", 44690.75),
            ("grad.l1.wk", 52595.99),
            ("grad.l1.wv", 161448.0),
            ("grad.l1.w_lin", 471023.4),
            ("grad.l2.w", 4.014812),
            ("grad.l2.a_src", 0.2996189),
            ("grad.l2.a_dst", 0.1011776),
            ("grad.l2.bias", 0.3320785),
            ("grad.l2.wq", 5.638868),
            ("grad.l2.wk", 6.623227),
            ("grad.l2.wv", 2.207232),
            ("grad.l2.w_lin", 8.093888),
        ],
    );
}

#[test]
fn native_edge_train_gat_matches_golden() {
    if !model_enabled("gat") {
        return;
    }
    check_golden(
        &builtin(),
        "edge_train_tiny_sim_gat_full",
        &[
            ("loss", 1.76483),
            ("logits", 1201.651),
            ("grad.l0.w", 2.893782),
            ("grad.l0.a_src", 0.3509826),
            ("grad.l0.a_dst", 0.04525009),
            ("grad.l0.bias", 3.503267),
            ("grad.l1.w", 23.17907),
            ("grad.l1.a_src", 0.4619403),
            ("grad.l1.a_dst", 0.02227038),
            ("grad.l1.bias", 2.239154),
            ("grad.l2.w", 8.714226),
            ("grad.l2.a_src", 0.0529282),
            ("grad.l2.a_dst", 0.001031094),
            ("grad.l2.bias", 0.5009941),
        ],
    );
}

// ---------------------------------------------------------------------------
// End-to-end on the native backend
// ---------------------------------------------------------------------------

fn epoch_losses(model: &str, seed: u64, epochs: usize) -> Vec<f32> {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds, model, "", NodeStrategy::Nodes, seed).unwrap();
    let mut out = Vec::new();
    for _ in 0..epochs {
        let mut acc = 0.0f32;
        let steps = 4; // 256 nodes / b=64
        for _ in 0..steps {
            acc += tr.train_step(&mut rt).unwrap();
        }
        out.push(acc / steps as f32);
    }
    out
}

#[test]
fn two_epoch_loss_descent_is_deterministic() {
    // Satellite requirement: a deterministic 2-epoch VqTrainer loss-descent
    // on the synthetic dataset, native backend only.
    if !model_enabled("gcn") {
        return;
    }
    let a = epoch_losses("gcn", 1, 2);
    assert!(
        a[1] < a[0],
        "mean loss did not descend over two epochs: {a:?}"
    );
    let b = epoch_losses("gcn", 1, 2);
    assert_eq!(a, b, "native training is not deterministic");
    for x in &a {
        assert!(x.is_finite());
    }
}

/// Learnable-convolution mirror of the two-epoch descent: attention
/// backbones spend their first batches converging the gradient codewords
/// (γ-EMA warm-up), so the deterministic descent window compares the first
/// two epoch means against epochs 5–6.  Seeds chosen for fat margins
/// (~45%+ in the spec-verified simulation of this exact trajectory).
fn attn_loss_descent(model: &str, seed: u64) {
    let m = epoch_losses(model, seed, 6);
    for x in &m {
        assert!(x.is_finite(), "{model}: non-finite epoch loss {m:?}");
    }
    let early = (m[0] + m[1]) / 2.0;
    let late = (m[4] + m[5]) / 2.0;
    assert!(
        late < early,
        "{model}: mean loss did not descend (epochs 1-2 {early:.4} vs 5-6 {late:.4}): {m:?}"
    );
    let again = epoch_losses(model, seed, 6);
    assert_eq!(m, again, "{model}: native training is not deterministic");
}

#[test]
fn two_epoch_loss_descent_gat() {
    if !model_enabled("gat") {
        return;
    }
    attn_loss_descent("gat", 3);
}

#[test]
fn two_epoch_loss_descent_txf() {
    if !model_enabled("txf") {
        return;
    }
    attn_loss_descent("txf", 5);
}

#[test]
fn native_backend_supports_all_backbones() {
    let man = builtin();
    let mut rt = Runtime::native();
    assert_eq!(rt.backend_name(), "native");
    for model in ["gcn", "sage", "gat", "txf"] {
        assert!(rt.supports_model(model), "{model} unsupported");
    }
    // The learnable convolutions compile natively now — no pjrt gate left.
    rt.load(&man, "vq_train_tiny_sim_gat").unwrap();
    rt.load(&man, "vq_train_tiny_sim_txf").unwrap();
    rt.load(&man, "edge_train_tiny_sim_gat_full").unwrap();
}

#[test]
fn txf_edge_trainer_fails_loudly_with_unsupported_edge_form() {
    // Satellite: the registry's typed error reaches EdgeTrainer users with
    // the reason, instead of the artifact silently not existing.
    if !model_enabled("txf") {
        return;
    }
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let err = match EdgeTrainer::new(&mut rt, &man, ds, "txf", Baseline::FullGraph, 1) {
        Ok(_) => panic!("EdgeTrainer accepted the txf backbone"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("UnsupportedEdgeForm"), "missing typed error: {err}");
    assert!(err.contains("no edge-list form"), "missing reason: {err}");
}

#[test]
fn vq_assign_artifact_masks_dims() {
    let man = builtin();
    let mut rt = Runtime::native();
    let art = rt.load(&man, "vq_assign_tiny_sim").unwrap();
    let spec = art.spec.clone();
    let (nb, b, fp) = (
        spec.inputs[0].shape[0],
        spec.inputs[0].shape[1],
        spec.inputs[0].shape[2],
    );
    let k = spec.k;
    let mut rng = Rng::new(9);
    let z: Vec<f32> = (0..nb * b * fp).map(|_| rng.gauss_f32()).collect();
    let cww: Vec<f32> = (0..nb * k * fp).map(|_| rng.gauss_f32()).collect();
    let mut run = |mask: Vec<f32>, zv: Vec<f32>| {
        let inputs = vec![
            Tensor::from_f32(&spec.inputs[0].shape, zv),
            Tensor::from_f32(&spec.inputs[1].shape, cww.clone()),
            Tensor::from_f32(&spec.inputs[2].shape, mask),
        ];
        rt.execute(&art, &inputs).unwrap()[0].i.clone()
    };
    // full mask: plain nearest-codeword
    let full = run(vec![1.0; nb * fp], z.clone());
    assert!(full.iter().all(|&a| (a as usize) < k));
    // half mask: poisoning the masked dims must not change assignments
    let mut mask = vec![0.0; nb * fp];
    for j in 0..nb {
        for d in 0..fp / 2 {
            mask[j * fp + d] = 1.0;
        }
    }
    let a1 = run(mask.clone(), z.clone());
    let mut zp = z.clone();
    for (i, x) in zp.iter_mut().enumerate() {
        if i % fp >= fp / 2 {
            *x = 1e5;
        }
    }
    let a2 = run(mask, zp);
    assert_eq!(a1, a2);
}

#[test]
fn infer_artifact_shares_forward_with_train() {
    // logits from vq_infer must match the logits output of vq_train on the
    // same inputs (same forward pass, loss head aside).
    let man = builtin();
    let mut rt = Runtime::native();
    let mut rng = Rng::new(41);
    let t_in = golden_inputs(&man, "vq_train_tiny_sim_gcn", &mut rng);
    let train_art = rt.load(&man, "vq_train_tiny_sim_gcn").unwrap();
    let infer_art = rt.load(&man, "vq_infer_tiny_sim_gcn").unwrap();
    let t_out = rt.execute(&train_art, &t_in).unwrap();
    let tspec = train_art.spec.clone();
    let ispec = infer_art.spec.clone();
    // project the train inputs onto the infer signature by name
    let i_in: Vec<Tensor> = ispec
        .inputs
        .iter()
        .map(|ts| t_in[tspec.input_index(&ts.name).unwrap()].clone())
        .collect();
    let i_out = rt.execute(&infer_art, &i_in).unwrap();
    let tl = &t_out[tspec.output_index("logits").unwrap()];
    let il = &i_out[ispec.output_index("logits").unwrap()];
    assert!(tl.max_abs_diff(il) < 1e-6);
}
