//! Concurrent-serving correctness, through the `ServeEngine` facade.
//!
//! Contracts under test:
//!
//! 1. **Pool determinism** — `ServeEngine::drain`/`poll` over a session
//!    pool of 1, 2 and 4 workers return bit-identical answers in submit
//!    order, including duplicate ids, padded tails, and interleaved
//!    node/link queries, on all four backbones.  (Each micro-batch is a
//!    pure function of the shared core; only latency stamps may differ.)
//! 2. **Deadline semantics** — partial tails are withheld by `poll` until
//!    a request's deadline expires (or `drain` forces them), and the two
//!    tail paths are counted separately.
//! 3. **Admission round-trip** — admit → serve → save (now "VQS3") →
//!    load → serve bit-identical, with admitted nodes usable as query
//!    targets, link endpoints, and neighbors of later admissions; legacy
//!    "VQS1" artifacts still load and serve the frozen nodes
//!    bit-identically.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (CI backbone matrix).

mod common;

use std::rc::Rc;
use std::time::Duration;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::{checkpoint, vq_trainer::VqTrainer};
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{Answer, Request, Served, ServeEngine, ServingModel};
use vq_gnn::util::rng::Rng;

const BACKBONES: [&str; 4] = ["gcn", "sage", "gat", "txf"];

fn trained(model: &str, steps: usize, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
            .unwrap();
    for _ in 0..steps {
        tr.train_step(&mut rt).unwrap();
    }
    (rt, man, ds, tr)
}

/// A mixed request stream exercising the hard cases: adjacent duplicates,
/// far-apart duplicates, interleaved link queries (two slots each), and a
/// slot count that is NOT a multiple of b (padded tail).
fn mixed_requests(n: usize, count: usize, b: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut reqs: Vec<Request> = (0..count)
        .map(|i| {
            if i % 5 == 3 {
                Request::Link(rng.below(n) as u32, rng.below(n) as u32)
            } else {
                Request::Node(rng.below(n) as u32)
            }
        })
        .collect();
    if let (Request::Node(v), true) = (reqs[0], matches!(reqs[1], Request::Node(_))) {
        reqs[1] = Request::Node(v); // adjacent duplicate in the first batch
    }
    if let Request::Node(v) = reqs[0] {
        let last = reqs.len() - 1;
        reqs[last] = Request::Node(v); // far-apart duplicate in the tail
    }
    let slots: usize = reqs
        .iter()
        .map(|r| if matches!(r, Request::Link(..)) { 2 } else { 1 })
        .sum();
    if slots % b == 0 {
        reqs.push(Request::Node(0)); // force a padded tail
    }
    reqs
}

/// Answers in submit order.  The engine's ticket sequence is global and
/// monotone across bursts, so order is checked RELATIVE to the burst's
/// first ticket, not absolute.
fn answers(served: &[Served]) -> Vec<Answer> {
    let first = served.first().map(|s| s.id).unwrap_or(0);
    for (i, s) in served.iter().enumerate() {
        assert_eq!(s.id, first + i, "answers out of submit order");
        assert!(s.latency_s >= 0.0);
    }
    served.iter().map(|s| s.answer.clone()).collect()
}

#[test]
fn pooled_flush_bit_identical_to_serial_drain() {
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, tr) = trained(model, 3, 7);
        let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let b = sm.batch_size();
        let reqs = mixed_requests(ds.n(), 150, b, 0xD15C ^ b as u64);

        let mut eng = ServeEngine::builder().model(model, sm).build(rt).unwrap();
        for &r in &reqs {
            eng.submit(model, r).unwrap();
        }
        let serial = answers(&eng.drain().unwrap());
        let base = eng.stats(model).unwrap().clone();
        assert!(base.padded_rows > 0, "{model}: stream must exercise padding");

        for threads in [2usize, 4] {
            eng.set_threads(threads);
            assert_eq!(eng.model(model).unwrap().threads(), threads);
            let pre = eng.stats(model).unwrap().clone();
            for &r in &reqs {
                eng.submit(model, r).unwrap();
            }
            let pooled = answers(&eng.drain().unwrap());
            assert_eq!(
                serial, pooled,
                "{model}: pooled drain at {threads} workers diverged from serial"
            );
            let st = eng.stats(model).unwrap();
            assert_eq!(st.batches_run - pre.batches_run, base.batches_run);
            assert_eq!(st.padded_rows - pre.padded_rows, base.padded_rows);
            // the pool actually spread the work
            let ws = eng.model(model).unwrap().worker_stats();
            assert_eq!(ws.len(), threads);
            assert!(
                ws.iter().filter(|w| w.batches > 0).count() > 1,
                "{model}: {threads}-worker pool left all work on one session"
            );
        }
    }
}

#[test]
fn deadline_withholds_tails_and_counts_both_paths() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 2, 11);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let b = sm.batch_size();
    let mut rng = Rng::new(3);

    // --- no deadline configured: poll never pads ------------------------
    let mut eng =
        ServeEngine::builder().model("gcn", sm).threads(2).build(rt).unwrap();
    let count = b + b / 2; // one full batch + a half tail
    for _ in 0..count {
        eng.submit("gcn", Request::Node(rng.below(ds.n()) as u32)).unwrap();
    }
    let first = eng.poll().unwrap();
    assert_eq!(first.len(), b, "only the full batch is served");
    assert_eq!(eng.pending(), b / 2, "tail stays queued");
    assert_eq!(eng.stats("gcn").unwrap().padded_rows, 0);
    assert_eq!(eng.stats("gcn").unwrap().full_batches, 1);
    // an idle poll with the same pending tail still withholds it
    assert!(eng.poll().unwrap().is_empty());
    // drain forces the tail (padded), counted as a FORCED tail flush
    let rest = eng.drain().unwrap();
    assert_eq!(rest.len(), b / 2);
    assert_eq!(rest[0].id, b, "ticket ids continue across flushes");
    let st = eng.stats("gcn").unwrap();
    assert_eq!(st.padded_rows as usize, b - b / 2);
    assert_eq!(st.tail_forced_flushes, 1);
    assert_eq!(st.tail_deadline_flushes, 0);

    // --- zero deadline: every poll behaves like a drain -----------------
    // (same frozen model, different queue discipline — into_parts hands
    // the model back without a re-freeze)
    let (rt, mut models) = eng.into_parts();
    let (name, sm) = models.remove(0);
    let mut eager = ServeEngine::builder()
        .model(name, sm)
        .threads(2)
        .deadline(Duration::from_millis(0))
        .build(rt)
        .unwrap();
    for _ in 0..(b / 2) {
        eager.submit("gcn", Request::Node(rng.below(ds.n()) as u32)).unwrap();
    }
    let all = eager.poll().unwrap();
    assert_eq!(all.len(), b / 2);
    let st = eager.stats("gcn").unwrap();
    assert_eq!(st.tail_deadline_flushes, 1);
    assert_eq!(st.tail_forced_flushes, 0);
    assert_eq!(st.last_flush_padded_rows as usize, b - b / 2);

    // --- a link query straddling the batch boundary is never split ------
    let (rt, mut models) = eager.into_parts();
    let (name, sm) = models.remove(0);
    let mut strad = ServeEngine::builder().model(name, sm).threads(2).build(rt).unwrap();
    for _ in 0..(b - 1) {
        strad.submit("gcn", Request::Node(rng.below(ds.n()) as u32)).unwrap();
    }
    strad.submit("gcn", Request::Link(1, 2)).unwrap(); // slots b-1 and b: crosses the cut
    assert!(strad.poll().unwrap().is_empty(), "no whole batch packs");
    assert_eq!(strad.pending(), b);
    let forced = strad.drain().unwrap();
    assert_eq!(forced.len(), b);
    assert!(matches!(forced[b - 1].answer, Answer::Link(_)));
}

#[test]
fn admission_roundtrip_serves_cold_nodes_across_save_load() {
    let dir = std::env::temp_dir().join("vqgnn_serve_admit_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, tr) = trained(model, 3, 13);
        let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let n = ds.n() as u32;

        // VQS1 export of the pre-admission state (legacy compatibility)
        let v1_path = dir.join(format!("{model}.v1.bin"));
        checkpoint::save_serving_v1(
            &v1_path,
            &sm.core.art.spec.name,
            &sm.core.params,
            &sm.core.cache.to_serving_layers(),
        )
        .unwrap();

        // baseline answers for frozen nodes, pre-admission
        let mut eng = ServeEngine::builder().model(model, sm).build(rt).unwrap();
        let frozen_q: Vec<Request> = (0..6).map(|i| Request::Node(i * 7 % n)).collect();
        for &r in &frozen_q {
            eng.submit(model, r).unwrap();
        }
        let before = answers(&eng.drain().unwrap());

        // admit two cold nodes; the second cites the first as a neighbor
        let mut feat: Vec<f32> = ds.feature_row(3).to_vec();
        for (i, x) in feat.iter_mut().enumerate() {
            *x += 0.01 * (i as f32 + 1.0);
        }
        let a = eng.admit(model, &feat, &[1, 5, 9]).unwrap();
        assert_eq!(a, n);
        let b_id = eng.admit(model, &feat[..ds.cfg.f_in], &[a, 2]).unwrap();
        assert_eq!(b_id, n + 1);
        assert_eq!(eng.model(model).unwrap().total_nodes(), ds.n() + 2);

        // cold nodes are first-class: direct queries, link endpoints,
        // neighbors-of-admitted — pooled across 2 workers
        eng.set_threads(2);
        let mix: Vec<Request> = vec![
            Request::Node(a),
            Request::Node(b_id),
            Request::Link(a, 3),
            Request::Node(2),
            Request::Link(b_id, a),
            Request::Node(a),
        ];
        for &r in &mix {
            eng.submit(model, r).unwrap();
        }
        let admitted_ans = answers(&eng.drain().unwrap());
        assert_eq!(admitted_ans[0], admitted_ans[5], "duplicate cold queries agree");

        // save ("VQS2") → load → hot-add behind a second routing name →
        // serve bit-identical
        let path = dir.join(format!("{model}.v2.bin"));
        eng.model(model).unwrap().save(&path).unwrap();
        let sm2 =
            ServingModel::load(eng.runtime_mut(), &man, ds.clone(), model, &path).unwrap();
        assert_eq!(sm2.total_nodes(), ds.n() + 2);
        assert_eq!(
            eng.model(model).unwrap().cache().memory_bytes(),
            sm2.cache().memory_bytes()
        );
        eng.add_model("reloaded", sm2).unwrap();
        for &r in &mix {
            eng.submit("reloaded", r).unwrap();
        }
        let reloaded_ans = answers(&eng.drain().unwrap());
        assert_eq!(
            admitted_ans, reloaded_ans,
            "{model}: VQS2 round-trip changed admitted-node answers"
        );

        // frozen-node answers are untouched by admission on local-only
        // backbones (txf's global attention legitimately sees the new
        // nodes through the codeword histogram)
        if model != "txf" {
            for &r in &frozen_q {
                eng.submit(model, r).unwrap();
            }
            let after = answers(&eng.drain().unwrap());
            assert_eq!(before, after, "{model}: admission perturbed frozen nodes");
        }

        // the legacy VQS1 artifact still loads and serves frozen nodes
        // bit-identically to the pre-admission model
        let sm_v1 =
            ServingModel::load(eng.runtime_mut(), &man, ds.clone(), model, &v1_path).unwrap();
        eng.add_model("v1", sm_v1).unwrap();
        for &r in &frozen_q {
            eng.submit("v1", r).unwrap();
        }
        let v1_ans = answers(&eng.drain().unwrap());
        assert_eq!(before, v1_ans, "{model}: VQS1 compatibility load drifted");
        // and admission on a VQS1 model still works (identity whitening)
        let v1_id = eng.admit("v1", &feat, &[1, 2]).unwrap();
        assert_eq!(v1_id, n);
        eng.submit("v1", Request::Node(v1_id)).unwrap();
        assert_eq!(eng.drain().unwrap().len(), 1);
    }
}

#[test]
fn queued_admissions_apply_fifo_with_dense_ids() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 2, 5);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let n = ds.n() as u32;
    let feat = ds.feature_row(0).to_vec();
    let mut eng = ServeEngine::builder().model("gcn", sm).build(rt).unwrap();

    let smm = eng.model_mut("gcn").unwrap();
    let first = smm.queue_admission(feat.clone(), vec![4, 8]).unwrap();
    assert_eq!(first, n);
    // the second request may cite the first's provisional id...
    let second = smm.queue_admission(feat.clone(), vec![first]).unwrap();
    assert_eq!(second, n + 1);
    // ...but not a future one
    assert!(smm.queue_admission(feat.clone(), vec![n + 5]).is_err());
    assert_eq!(smm.queued_admissions(), 2);
    // a direct admit would steal the first queued node's promised id
    assert!(eng.admit("gcn", &feat, &[]).is_err());
    let ids = eng.admit_queued("gcn").unwrap();
    assert_eq!(ids, vec![first, second]);
    assert_eq!(eng.model("gcn").unwrap().queued_admissions(), 0);
    eng.submit("gcn", Request::Node(second)).unwrap();
    let served = eng.drain().unwrap();
    assert!(matches!(served[0].answer, Answer::Scores(_)));

    // admission rejects garbage without poisoning the model
    assert!(eng.admit("gcn", &[f32::NAN; 4], &[]).is_err());
    assert!(eng.admit("gcn", &feat, &[9999]).is_err());
    assert!(eng.admit("gcn", &feat[..1], &[]).is_err());
    assert_eq!(
        eng.model("gcn").unwrap().total_nodes(),
        ds.n() + 2,
        "failed admissions left no residue"
    );

    // malformed requests are refused AT ENQUEUE — they can never sit in
    // front of valid queued admissions
    let smm = eng.model_mut("gcn").unwrap();
    let bad: Vec<f32> = vec![f32::NAN; feat.len()];
    assert!(smm.queue_admission(bad, vec![]).is_err(), "NaN features refused at enqueue");
    assert!(smm.queue_admission(feat[..1].to_vec(), vec![]).is_err(), "short row refused");
    assert_eq!(smm.queued_admissions(), 0);

    // a queued-but-unapplied request reserves its id; clearing releases it
    let reserved = smm.queue_admission(feat.clone(), vec![0]).unwrap();
    assert_eq!(reserved, n + 2);
    assert!(eng.admit("gcn", &feat, &[]).is_err(), "direct admit blocked while queued");
    eng.model_mut("gcn").unwrap().clear_queued();
    let next = eng.admit("gcn", &feat, &[]).unwrap();
    assert_eq!(next, n + 2, "clearing the queue releases the reserved id");
}
