//! Socket front-end contracts (`serve::server` + `serve::proto` over real
//! loopback TCP):
//!
//! 1. **Bit-identity** — answers served over the socket are byte-identical
//!    to the file-driven path (same poll-then-drain partition of the slot
//!    stream) for all four backbones, mixed node/link streams included.
//! 2. **Failure containment** — a malformed frame earns a typed ERROR and
//!    the connection survives; an unusable length prefix earns the ERROR
//!    and a hang-up; a mid-frame disconnect is reported as a truncation;
//!    an unknown model or bad node id is a per-request error; none of
//!    these poison the engine for later connections.
//! 3. **Load shedding** — a saturated bounded queue refuses the overflow
//!    with SHED frames while every accepted request is still answered.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (CI backbone matrix).

mod common;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::time::Duration;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::proto::{
    decode_response, encode_request, read_frame, ErrCode, WireRequest, WireResponse, NO_REQ_ID,
};
use vq_gnn::serve::{server, Answer, Request, ServeEngine, ServerReport, ServingModel};
use vq_gnn::util::rng::Rng;

const BACKBONES: [&str; 4] = ["gcn", "sage", "gat", "txf"];

fn trained(model: &str, steps: usize, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
            .unwrap();
    for _ in 0..steps {
        tr.train_step(&mut rt).unwrap();
    }
    (rt, man, ds, tr)
}

fn mixed_requests(n: usize, count: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            if i % 5 == 3 {
                Request::Link(rng.below(n) as u32, rng.below(n) as u32)
            } else {
                Request::Node(rng.below(n) as u32)
            }
        })
        .collect()
}

fn to_wire(model: &str, req_id: u64, req: Request) -> WireRequest {
    match req {
        Request::Node(v) => WireRequest::Node { req_id, model: model.to_string(), node: v },
        Request::Link(u, v) => {
            WireRequest::Link { req_id, model: model.to_string(), u, v }
        }
    }
}

#[test]
fn socket_roundtrip_bit_identical_to_file_driven() {
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, tr) = trained(model, 3, 7);
        // two freezes of one trainer are the same model: one serves the
        // socket, one the file-driven reference
        let sm_srv = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let sm_file = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let reqs = mixed_requests(ds.n(), 150, 0xBEEF ^ sm_file.batch_size() as u64);

        // file-driven reference: the CLI's poll-then-drain discipline
        let mut fe = ServeEngine::builder()
            .model(model, sm_file)
            .threads(4)
            .deadline(Duration::from_secs(10))
            .build(rt)
            .unwrap();
        for &r in &reqs {
            fe.submit(model, r).unwrap();
        }
        let mut want = fe.poll().unwrap();
        want.extend(fe.drain().unwrap());
        want.sort_by_key(|s| s.id);
        let want: Vec<Answer> = want.into_iter().map(|s| s.answer).collect();

        let mut se = ServeEngine::builder()
            .model(model, sm_srv)
            .threads(4)
            .deadline(Duration::from_secs(10))
            .build(Runtime::native())
            .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (report, got) = std::thread::scope(|s| {
            let reqs = &reqs;
            let client = s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                for (i, &r) in reqs.iter().enumerate() {
                    stream
                        .write_all(&encode_request(&to_wire(model, i as u64, r)))
                        .unwrap();
                }
                stream.write_all(&encode_request(&WireRequest::Shutdown)).unwrap();
                let mut got: Vec<(u64, Answer)> = Vec::new();
                while let Some(p) = read_frame(&mut stream).unwrap() {
                    match decode_response(&p).unwrap() {
                        WireResponse::Scores { req_id, embedding, row } => {
                            assert!(!embedding, "tiny_sim is a node task");
                            got.push((req_id, Answer::Scores(row)));
                        }
                        WireResponse::Link { req_id, score } => {
                            got.push((req_id, Answer::Link(score)));
                        }
                        other => panic!("unexpected response {other:?}"),
                    }
                }
                got.sort_by_key(|(id, _)| *id);
                got
            });
            let report = server::run(&mut se, listener).unwrap();
            (report, client.join().unwrap())
        });

        assert_eq!(got.len(), reqs.len(), "{model}: every request answered");
        for (i, (id, _)) in got.iter().enumerate() {
            assert_eq!(*id, i as u64, "{model}: response ids are dense");
        }
        let got: Vec<Answer> = got.into_iter().map(|(_, a)| a).collect();
        assert_eq!(got, want, "{model}: socket answers diverged from file-driven path");
        assert_eq!(
            report,
            ServerReport {
                connections: 1,
                requests: reqs.len() as u64,
                served: reqs.len() as u64,
                shed: 0,
                errors: 0,
            }
        );
    }
}

#[test]
fn protocol_violations_are_contained_per_connection() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 2, 11);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let mut se = ServeEngine::builder().model("gcn", sm).threads(2).build(rt).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let probe = server::ServerProbe::new();

    let report = std::thread::scope(|s| {
        let probe = &probe;
        s.spawn(move || {
            let read_err = |stream: &mut TcpStream| -> (u64, ErrCode, String) {
                let p = read_frame(stream).unwrap().expect("error frame");
                match decode_response(&p).unwrap() {
                    WireResponse::Error { req_id, code, msg } => (req_id, code, msg),
                    other => panic!("expected ERROR, got {other:?}"),
                }
            };

            // ---- A: undecodable payload — typed error, connection
            // SURVIVES (framing is still aligned) ----------------------
            let mut a = TcpStream::connect(addr).unwrap();
            a.write_all(&1u32.to_le_bytes()).unwrap();
            a.write_all(&[0x7f]).unwrap(); // unknown kind byte
            let (rid, code, msg) = read_err(&mut a);
            assert_eq!(rid, NO_REQ_ID, "unparsed frame carries no request id");
            assert_eq!(code, ErrCode::Malformed);
            assert!(!msg.is_empty());
            let node = WireRequest::Node { req_id: 11, model: "gcn".into(), node: 3 };
            a.write_all(&encode_request(&node)).unwrap();
            a.write_all(&encode_request(&WireRequest::Drain)).unwrap();
            let p = read_frame(&mut a).unwrap().expect("answer after the bad frame");
            assert!(
                matches!(decode_response(&p).unwrap(),
                         WireResponse::Scores { req_id: 11, .. }),
                "connection kept serving after a malformed frame"
            );
            drop(a);

            // ---- B: unusable length prefix — typed error, then hang-up
            let mut b = TcpStream::connect(addr).unwrap();
            b.write_all(&(2u32 * 1024 * 1024).to_le_bytes()).unwrap();
            b.write_all(&[0u8; 8]).unwrap();
            let (rid, code, _) = read_err(&mut b);
            assert_eq!(rid, NO_REQ_ID);
            assert_eq!(code, ErrCode::Malformed);
            assert!(
                read_frame(&mut b).unwrap().is_none(),
                "server hangs up after an oversized prefix"
            );
            drop(b);

            // ---- C: disconnect mid-frame — a typed truncation server-side
            // (asserted via the report), later connections unaffected --
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&100u32.to_le_bytes()).unwrap();
            c.write_all(&[1, 2, 3]).unwrap();
            drop(c);
            // real synchronization point: A and B put the probe at 2
            // errors, so wait (bounded, no sleep) until the batcher has
            // COUNTED C's truncation as the 3rd before the shutdown
            // below can end the run
            let spin = std::time::Instant::now();
            while probe.errors() < 3 {
                assert!(
                    spin.elapsed() < Duration::from_secs(10),
                    "truncation error never surfaced (probe stuck at {})",
                    probe.errors()
                );
                std::thread::yield_now();
            }

            // ---- D: per-request errors, then normal service ----------
            let mut d = TcpStream::connect(addr).unwrap();
            let bad_model = WireRequest::Node { req_id: 70, model: "nope".into(), node: 0 };
            d.write_all(&encode_request(&bad_model)).unwrap();
            let (rid, code, msg) = read_err(&mut d);
            assert_eq!(rid, 70, "routing errors keep the request id");
            assert_eq!(code, ErrCode::UnknownModel);
            assert!(msg.contains("nope"));
            let bad_node =
                WireRequest::Node { req_id: 71, model: "gcn".into(), node: 999_999 };
            d.write_all(&encode_request(&bad_node)).unwrap();
            let (rid, code, _) = read_err(&mut d);
            assert_eq!(rid, 71);
            assert_eq!(code, ErrCode::BadRequest);
            d.write_all(&encode_request(&WireRequest::Ping { req_id: 42 })).unwrap();
            let p = read_frame(&mut d).unwrap().expect("pong");
            assert_eq!(
                decode_response(&p).unwrap(),
                WireResponse::Pong { req_id: 42 }
            );
            let node = WireRequest::Node { req_id: 72, model: "gcn".into(), node: 5 };
            d.write_all(&encode_request(&node)).unwrap();
            d.write_all(&encode_request(&WireRequest::Drain)).unwrap();
            let p = read_frame(&mut d).unwrap().expect("scores");
            assert!(matches!(
                decode_response(&p).unwrap(),
                WireResponse::Scores { req_id: 72, .. }
            ));
            d.write_all(&encode_request(&WireRequest::Shutdown)).unwrap();
            while read_frame(&mut d).unwrap().is_some() {}
        });
        server::run_probed(&mut se, listener, probe).unwrap()
    });

    assert_eq!(
        report,
        ServerReport {
            connections: 4,
            requests: 4, // A's node + D's three node frames
            served: 2,   // A:11 and D:72
            shed: 0,
            // A bad kind, B oversize, C truncation, D unknown model,
            // D bad node id
            errors: 5,
        }
    );
}

#[test]
fn saturated_queue_sheds_over_the_socket() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 5);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    assert!(sm.batch_size() > 4, "cap must be below the batch width");
    // cap 4 slots, 10 s deadline: no full batch can form and no deadline
    // expires during the test, so exactly 4 of 10 requests are accepted
    // and the other 6 are shed — deterministically
    let mut se = ServeEngine::builder()
        .model("gcn", sm)
        .queue_cap(4)
        .deadline(Duration::from_secs(10))
        .build(rt)
        .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let (report, (scores, shed)) = std::thread::scope(|s| {
        let client = s.spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            for i in 0..10u64 {
                let node =
                    WireRequest::Node { req_id: i, model: "gcn".into(), node: i as u32 };
                stream.write_all(&encode_request(&node)).unwrap();
            }
            stream.write_all(&encode_request(&WireRequest::Shutdown)).unwrap();
            let (mut scores, mut shed) = (Vec::new(), Vec::new());
            while let Some(p) = read_frame(&mut stream).unwrap() {
                match decode_response(&p).unwrap() {
                    WireResponse::Scores { req_id, .. } => scores.push(req_id),
                    WireResponse::Error { req_id, code, msg } => {
                        assert_eq!(code, ErrCode::Shed, "only SHED refusals expected");
                        assert!(!msg.is_empty());
                        shed.push(req_id);
                    }
                    other => panic!("unexpected response {other:?}"),
                }
            }
            scores.sort_unstable();
            shed.sort_unstable();
            (scores, shed)
        });
        let report = server::run(&mut se, listener).unwrap();
        (report, client.join().unwrap())
    });

    assert_eq!(scores, vec![0, 1, 2, 3], "accepted requests are still answered");
    assert_eq!(shed, vec![4, 5, 6, 7, 8, 9], "the overflow is shed FIFO");
    assert_eq!(
        report,
        ServerReport { connections: 1, requests: 10, served: 4, shed: 6, errors: 0 }
    );
}
