//! Finite-difference gradient checks for the native interpreter's hand-
//! derived backward passes — all four backbones (gcn, sage, gat, txf) on
//! the VQ path and the three edge-list baselines.  This is the reusable
//! harness that makes every future backbone cheap to add: implement the
//! forward + VJP, register the artifact, append one line here.
//!
//! ## What is (and isn't) checkable by finite differences
//!
//! The Eq. 7 custom VJP *adds* the out-of-batch gradient messages — the
//! transposed sketches riding the gradient half of the codewords — on top
//! of the true gradient of the computed forward.  Those extra terms enter
//! ∂ℓ/∂X_B at each layer, so they only perturb the gradients of *lower*
//! layers.  Two complementary checks follow:
//!
//! 1. all-layers, transposed inputs zeroed: with `ct_out` / `m_out_t` = 0
//!    and (txf) `cnt_out` = 0 the extra terms vanish and every parameter's
//!    VJP is the true gradient (`cnt_out` = 0 also silences the global
//!    branch's *forward* out-of-batch block — covered by check 2);
//! 2. last-layer, full inputs: nothing zeroed, so the out-of-batch forward
//!    scores (including the `cnt_out`-weighted global block, the codeword
//!    dot-product paths into wq/wk, and their denominators) are live — the
//!    last layer's parameter gradients are still exact because no Eq. 7
//!    extra term sits above them.
//!
//! The Eq. 7 extra terms themselves are pinned by the golden tests, whose
//! values were verified elementwise against the repo's JAX executable spec
//! under `jax.value_and_grad`.
//!
//! ## Numerics
//!
//! The interpreter is f32 and the network is piecewise-smooth (ReLU,
//! LeakyReLU, score caps), so a single step size cannot serve every
//! parameter tensor: large eps crosses kinks (the FD blends slopes),
//! small eps amplifies f32 rounding of the loss.  Each tensor therefore
//! takes a central difference along one random unit direction at several
//! step sizes and must agree with the analytic directional derivative at
//! one of them, with the error measured against max(|fd|, |analytic|, 1)
//! — loss gradients here are O(1), so this is a relative check.  The
//! tolerances (1e-3 vq / 3e-3 edge, the edge paths sum over 4× more rows
//! and carry proportionally more f32 noise) hold with ≥5× margin in the
//! f32 simulation of this exact procedure.

mod common;

use common::{builtin, golden_inputs, model_enabled};
use vq_gnn::runtime::Runtime;
use vq_gnn::util::rng::Rng;
use vq_gnn::util::tensor::Tensor;

const EPS_SET: [f32; 4] = [1e-2, 3e-3, 1e-3, 3e-4];

/// Zero the inputs that only feed the Eq. 7 out-of-batch backward messages.
fn zero_backward_only_inputs(spec_names: &[String], inputs: &mut [Tensor]) {
    for (name, t) in spec_names.iter().zip(inputs.iter_mut()) {
        let backward_only = name.ends_with(".ct_out")
            || name.ends_with(".m_out_t")
            || name.ends_with(".cnt_out");
        if backward_only {
            for x in t.f.iter_mut() {
                *x = 0.0;
            }
        }
    }
}

/// Check 1: every parameter tensor, with the Eq. 7 transposed inputs zeroed.
fn gradcheck(artifact: &str, seed: u64, tol: f64) {
    gradcheck_impl(artifact, seed, tol, false);
}

/// Check 2: the last layer's parameter tensors under the FULL custom VJP
/// (nothing zeroed) — exercises the out-of-batch forward score paths.
fn gradcheck_last_layer_full(artifact: &str, seed: u64, tol: f64) {
    gradcheck_impl(artifact, seed, tol, true);
}

fn gradcheck_impl(artifact: &str, seed: u64, tol: f64, full_inputs: bool) {
    let man = builtin();
    let mut rt = Runtime::native();
    let art = rt.load(&man, artifact).unwrap();
    let spec = art.spec.clone();
    assert_eq!(spec.outputs[0].name, "loss");
    let names: Vec<String> = spec.inputs.iter().map(|t| t.name.clone()).collect();
    let mut inputs = golden_inputs(&man, artifact, &mut Rng::new(seed));
    let prefix = if full_inputs {
        format!("param.l{}.", spec.plan.len().max(1) - 1)
    } else {
        zero_backward_only_inputs(&names, &mut inputs);
        "param.".to_string()
    };
    let base = rt.execute(&art, &inputs).unwrap();

    let pidx: Vec<usize> = (0..names.len()).filter(|&i| names[i].starts_with(&prefix)).collect();
    assert!(!pidx.is_empty(), "{artifact}: no params match '{prefix}'");
    for &pi in &pidx {
        let pname = &names[pi];
        // One random unit direction per tensor (seeded by the name).
        let mut drng = Rng::new((seed ^ 0xD1F).wrapping_add(pname.len() as u64));
        let mut u: Vec<f32> = (0..inputs[pi].numel()).map(|_| drng.gauss_f32()).collect();
        let norm = u.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32;
        for x in u.iter_mut() {
            *x /= norm;
        }
        let gi = spec
            .output_index(&format!("grad.{}", &pname["param.".len()..]))
            .unwrap_or_else(|| panic!("{artifact}: no grad output for {pname}"));
        let an: f64 = base[gi]
            .f
            .iter()
            .zip(&u)
            .map(|(&g, &d)| g as f64 * d as f64)
            .sum();

        let saved = inputs[pi].clone();
        let mut best = f64::INFINITY;
        let mut best_eps = 0.0f32;
        for eps in EPS_SET {
            let perturb = |inputs: &mut [Tensor], sign: f32| {
                let data: Vec<f32> =
                    saved.f.iter().zip(&u).map(|(&p, &d)| p + sign * eps * d).collect();
                inputs[pi] = Tensor::from_f32(&saved.shape, data);
            };
            perturb(&mut inputs, 1.0);
            let lp = rt.execute(&art, &inputs).unwrap()[0].f[0] as f64;
            perturb(&mut inputs, -1.0);
            let lm = rt.execute(&art, &inputs).unwrap()[0].f[0] as f64;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let rel = (fd - an).abs() / fd.abs().max(an.abs()).max(1.0);
            if rel < best {
                best = rel;
                best_eps = eps;
            }
            if best < tol {
                break; // this tensor's VJP is confirmed
            }
        }
        inputs[pi] = saved;
        assert!(
            best < tol,
            "{artifact}/{pname}: finite differences disagree with the analytic \
             gradient — best rel err {best:.3e} at eps {best_eps:.0e} \
             (analytic directional derivative {an:+.6e}, tol {tol:.0e})"
        );
    }
}

#[test]
fn gradcheck_vq_gcn() {
    if model_enabled("gcn") {
        gradcheck("vq_train_tiny_sim_gcn", 778, 1e-3);
    }
}

#[test]
fn gradcheck_vq_sage() {
    if model_enabled("sage") {
        gradcheck("vq_train_tiny_sim_sage", 778, 1e-3);
    }
}

#[test]
fn gradcheck_vq_gat() {
    if model_enabled("gat") {
        gradcheck("vq_train_tiny_sim_gat", 778, 1e-3);
    }
}

#[test]
fn gradcheck_vq_txf() {
    if model_enabled("txf") {
        gradcheck("vq_train_tiny_sim_txf", 778, 1e-3);
    }
}

#[test]
fn gradcheck_vq_gat_full_eq7_last_layer() {
    if model_enabled("gat") {
        gradcheck_last_layer_full("vq_train_tiny_sim_gat", 778, 1e-3);
    }
}

#[test]
fn gradcheck_vq_txf_full_eq7_last_layer() {
    if model_enabled("txf") {
        gradcheck_last_layer_full("vq_train_tiny_sim_txf", 778, 1e-3);
    }
}

#[test]
fn gradcheck_edge_gcn() {
    if model_enabled("gcn") {
        gradcheck("edge_train_tiny_sim_gcn_full", 777, 3e-3);
    }
}

#[test]
fn gradcheck_edge_sage() {
    if model_enabled("sage") {
        gradcheck("edge_train_tiny_sim_sage_full", 777, 3e-3);
    }
}

#[test]
fn gradcheck_edge_gat() {
    if model_enabled("gat") {
        gradcheck("edge_train_tiny_sim_gat_full", 777, 3e-3);
    }
}
