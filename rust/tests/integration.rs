//! Integration tests over the runtime + coordinator on tiny_sim: golden
//! replay (execution == python numerics, when AOT golden bundles exist),
//! end-to-end VQ-GNN and baseline training to planted-signal accuracy,
//! padding invariance, and the inductive inference path.
//!
//! These run hermetically on the default native backend (builtin manifest,
//! no Python / JAX / artifacts directory); with `VQ_GNN_BACKEND=pjrt` and
//! AOT artifacts they exercise the PJRT path unchanged.

use std::path::Path;
use std::rc::Rc;

use vq_gnn::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::{Golden, Runtime};
use vq_gnn::sampler::NodeStrategy;

fn artifacts_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn setup() -> (Runtime, Manifest) {
    let man = Manifest::load_or_builtin(artifacts_dir());
    (Runtime::new().unwrap(), man)
}

#[test]
fn golden_replay_all_bundles() {
    let (mut rt, man) = setup();
    let groot = artifacts_dir().join("goldens");
    if !groot.exists() {
        // Golden bundles are produced by the AOT pipeline; hermetic
        // checkouts exercise the native golden tests instead
        // (tests/native_backend.rs).
        eprintln!("skipping golden replay: {} not present", groot.display());
        return;
    }
    let mut checked = 0;
    for entry in std::fs::read_dir(&groot).unwrap() {
        let dir = entry.unwrap().path();
        if !dir.is_dir() {
            continue;
        }
        let name = dir.file_name().unwrap().to_str().unwrap().to_string();
        let golden = Golden::load(&dir).unwrap();
        let art = match rt.load(&man, &name) {
            Ok(a) => a,
            Err(e) => {
                // e.g. an artifact family this backend cannot compile
                eprintln!("skipping golden {name}: {e:#}");
                continue;
            }
        };
        let inputs: Vec<_> = golden.inputs.iter().map(|(_, t)| t.clone()).collect();
        let outputs = rt.execute(&art, &inputs).unwrap();
        let pjrt = rt.backend_name() == "pjrt";
        for ((oname, want), got) in golden.outputs.iter().zip(&outputs) {
            match want.dtype {
                vq_gnn::util::tensor::DType::F32 => {
                    let rel = got.rel_l2(want);
                    assert!(rel < 2e-4, "{name}/{oname}: rel err {rel}");
                }
                vq_gnn::util::tensor::DType::I32 if pjrt => {
                    assert_eq!(got.i, want.i, "{name}/{oname}");
                }
                vq_gnn::util::tensor::DType::I32 => {
                    // Cross-backend assignment replay: the native distance
                    // decomposition may flip exact near-ties vs XLA — bound
                    // the rate instead of demanding bit equality.
                    let n = want.i.len().max(1);
                    let mism =
                        got.i.iter().zip(&want.i).filter(|(a, b)| a != b).count();
                    assert!(mism * 200 < n, "{name}/{oname}: {mism}/{n} flips");
                }
            }
        }
        checked += 1;
    }
    let want = if rt.backend_name() == "pjrt" { 5 } else { 1 };
    assert!(checked >= want, "only {checked} golden bundles replayed");
}

#[test]
fn vq_gcn_trains_tiny_to_signal() {
    let (mut rt, man) = setup();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds, "gcn", "", NodeStrategy::Nodes, 1).unwrap();
    let acc0 = tr.evaluate(&mut rt, Split::Val).unwrap();
    let mut first_loss = None;
    let mut last = 0.0;
    for _ in 0..30 {
        last = tr.epoch(&mut rt).unwrap();
        first_loss.get_or_insert(last);
    }
    let acc = tr.evaluate(&mut rt, Split::Val).unwrap();
    assert!(last < first_loss.unwrap(), "loss did not decrease");
    assert!(acc > 0.80, "val acc {acc} (untrained {acc0}); tiny_sim has 4 planted classes");
    assert!(acc > acc0 + 0.2);
}

#[test]
fn vq_sage_and_gat_train_tiny() {
    let (mut rt, man) = setup();
    // GAT's learnable convolution trains noisier under VQ early on (the
    // attention codewords must converge first), so it gets more epochs and
    // a looser bar than the fixed-convolution backbones.
    for (model, epochs, bar) in [("sage", 25, 0.70), ("gat", 45, 0.45)] {
        if !rt.supports_model(model) {
            eprintln!("skipping {model}: unsupported on the {} backend", rt.backend_name());
            continue;
        }
        let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
        let mut tr =
            VqTrainer::new(&mut rt, &man, ds, model, "", NodeStrategy::Nodes, 2).unwrap();
        let mut best = 0.0f64;
        for e in 0..epochs {
            tr.epoch(&mut rt).unwrap();
            if e % 5 == 4 {
                best = best.max(tr.evaluate(&mut rt, Split::Val).unwrap());
            }
        }
        best = best.max(tr.evaluate(&mut rt, Split::Val).unwrap());
        assert!(best > bar, "{model}: best val acc {best}");
    }
}

#[test]
fn full_graph_baseline_trains_tiny() {
    let (mut rt, man) = setup();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        EdgeTrainer::new(&mut rt, &man, ds, "gcn", Baseline::FullGraph, 3).unwrap();
    for _ in 0..150 {
        tr.train_step(&mut rt).unwrap();
    }
    let acc = tr.evaluate(&mut rt, Split::Val).unwrap();
    assert!(acc > 0.85, "full-graph val acc {acc}");
}

#[test]
fn vq_matches_full_graph_shape_tiny() {
    // The paper's core claim at miniature scale: VQ-GNN ends within a few
    // points of the full-graph oracle on the same data/backbone.
    let (mut rt, man) = setup();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut full =
        EdgeTrainer::new(&mut rt, &man, ds.clone(), "gcn", Baseline::FullGraph, 3).unwrap();
    for _ in 0..150 {
        full.train_step(&mut rt).unwrap();
    }
    let acc_full = full.evaluate(&mut rt, Split::Test).unwrap();
    let mut vq =
        VqTrainer::new(&mut rt, &man, ds, "gcn", "", NodeStrategy::Nodes, 1).unwrap();
    for _ in 0..40 {
        vq.epoch(&mut rt).unwrap();
    }
    let acc_vq = vq.evaluate(&mut rt, Split::Test).unwrap();
    assert!(
        acc_vq > acc_full - 0.08,
        "VQ {acc_vq} vs full {acc_full}: approximation gap too large"
    );
}

#[test]
fn padding_never_changes_unpadded_rows() {
    let (mut rt, man) = setup();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "", NodeStrategy::Nodes, 7).unwrap();
    // infer a node set smaller than b twice with different pad fillers —
    // identical logits required for the real rows
    let nodes: Vec<u32> = (0..10).collect();
    let l1 = tr.infer_nodes(&mut rt, &nodes).unwrap();
    let l2 = tr.infer_nodes(&mut rt, &nodes).unwrap();
    assert_eq!(l1, l2);
}
