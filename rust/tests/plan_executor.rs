//! Plan-compiled executor invariants.
//!
//! The arena refactor's whole contract is "same bits, no allocation": a
//! cached executor reusing one `StepArena` across steps must behave as a
//! pure function of its inputs, `execute_into` must compute the same
//! outputs into reused buffers as `execute` does into fresh ones, and the
//! trainers' pipelined batch assembly must walk the exact trajectory of
//! the serial schedule.  Golden values against the executable python spec
//! are pinned separately in `tests/native_backend.rs` / `tests/serve.rs`
//! (unchanged by the refactor — that is the point); this suite pins the
//! reuse semantics.

mod common;

use std::rc::Rc;

use common::{builtin, golden_inputs};
use vq_gnn::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::util::rng::Rng;
use vq_gnn::util::tensor::Tensor;

/// Bit-exact tensor-list equality (f32 compared by bit pattern).
fn assert_outputs_eq(a: &[Tensor], b: &[Tensor], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: output count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.shape, y.shape, "{what}: output {i} shape");
        assert_eq!(x.i, y.i, "{what}: output {i} i32 payload");
        let xb: Vec<u32> = x.f.iter().map(|v| v.to_bits()).collect();
        let yb: Vec<u32> = y.f.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xb, yb, "{what}: output {i} f32 bits");
    }
}

/// The shared-plan/per-session split (ISSUE 5): sessions detached from one
/// executable are bit-identical to the executable's built-in path, both
/// serially and when several sessions drive the SAME `&Executable` from
/// concurrent `util::par` workers at once.
#[test]
fn detached_sessions_match_execute_serial_and_concurrent() {
    let man = builtin();
    for name in all_artifacts() {
        let mut rng = Rng::new(0x5E55 ^ name.len() as u64);
        let mut rt = Runtime::native();
        let art = rt.load(&man, name).unwrap();
        let inputs = golden_inputs(&man, name, &mut rng);
        let want = rt.execute(&art, &inputs).unwrap();

        // one detached session via the Runtime entry point
        let mut sess = art.new_session();
        let mut out = Vec::new();
        rt.run_session(&art, &inputs, &mut out, &mut sess).unwrap();
        assert_outputs_eq(&out, &want, &format!("{name} (detached session)"));
        // reused session buffers stay bit-identical
        rt.run_session(&art, &inputs, &mut out, &mut sess).unwrap();
        assert_outputs_eq(&out, &want, &format!("{name} (reused session)"));

        // four sessions over the SAME executable, concurrently
        let artr: &vq_gnn::runtime::Artifact = &art;
        let mut states: Vec<(vq_gnn::runtime::ExecSession, Vec<Tensor>)> =
            (0..4).map(|_| (artr.new_session(), Vec::new())).collect();
        let results = vq_gnn::util::par::scope_map(&mut states, |_w, state| {
            artr.run_session(&inputs, &mut state.1, &mut state.0)
        });
        for r in results {
            r.unwrap();
        }
        for (w, (_, out)) in states.iter().enumerate() {
            assert_outputs_eq(out, &want, &format!("{name} (concurrent session {w})"));
        }
    }
}

/// Every artifact family × mode the native backend compiles, on the tiny
/// hermetic config.
fn all_artifacts() -> Vec<&'static str> {
    vec![
        "vq_train_tiny_sim_gcn",
        "vq_train_tiny_sim_sage",
        "vq_train_tiny_sim_gat",
        "vq_train_tiny_sim_txf",
        "vq_infer_tiny_sim_gcn",
        "vq_infer_tiny_sim_sage",
        "vq_infer_tiny_sim_gat",
        "vq_infer_tiny_sim_txf",
        "vq_serve_tiny_sim_gcn",
        "vq_serve_tiny_sim_sage",
        "vq_serve_tiny_sim_gat",
        "vq_serve_tiny_sim_txf",
        "edge_train_tiny_sim_gcn_full",
        "edge_train_tiny_sim_sage_full",
        "edge_train_tiny_sim_gat_full",
        "edge_infer_tiny_sim_gcn_full",
        "vq_assign_tiny_sim",
    ]
}

#[test]
fn cached_arena_is_a_pure_function_of_inputs() {
    // Two different input sets A and B through ONE cached executor (reused
    // arena), interleaved A, B, A — every run must be bit-identical to a
    // fresh executor fed the same inputs.  This is the strongest form of
    // "the arena carries no semantic state across steps": stale buffer
    // contents from run A must never leak into run B or back.
    let man = builtin();
    for name in all_artifacts() {
        let mut rng_a = Rng::new(1234);
        let mut rng_b = Rng::new(987654321);
        let in_a = golden_inputs(&man, name, &mut rng_a);
        let in_b = golden_inputs(&man, name, &mut rng_b);

        let mut shared = Runtime::native();
        let art = shared.load(&man, name).unwrap();
        let a1 = shared.execute(&art, &in_a).unwrap();
        let b1 = shared.execute(&art, &in_b).unwrap();
        let a2 = shared.execute(&art, &in_a).unwrap();

        let mut fresh_a = Runtime::native();
        let fa = fresh_a.load(&man, name).unwrap();
        let want_a = fresh_a.execute(&fa, &in_a).unwrap();
        let mut fresh_b = Runtime::native();
        let fb = fresh_b.load(&man, name).unwrap();
        let want_b = fresh_b.execute(&fb, &in_b).unwrap();

        assert_outputs_eq(&a1, &want_a, &format!("{name} (first run vs fresh)"));
        assert_outputs_eq(&b1, &want_b, &format!("{name} (second run vs fresh)"));
        assert_outputs_eq(&a2, &want_a, &format!("{name} (reused arena vs fresh)"));
    }
}

#[test]
fn execute_into_matches_execute_with_reused_buffers() {
    // The session path: one `outputs` vector rewritten in place across
    // consecutive executions must hold exactly what fresh `execute` calls
    // return — including after switching between two different input sets,
    // so every output element is proven overwritten (not stale).
    let man = builtin();
    for name in all_artifacts() {
        let mut rng_a = Rng::new(42);
        let mut rng_b = Rng::new(777);
        let in_a = golden_inputs(&man, name, &mut rng_a);
        let in_b = golden_inputs(&man, name, &mut rng_b);
        let mut rt = Runtime::native();
        let art = rt.load(&man, name).unwrap();
        let want_a = rt.execute(&art, &in_a).unwrap();
        let want_b = rt.execute(&art, &in_b).unwrap();
        let mut outputs = Vec::new();
        rt.execute_into(&art, &in_a, &mut outputs).unwrap();
        assert_outputs_eq(&outputs, &want_a, &format!("{name} (into, run 1)"));
        rt.execute_into(&art, &in_b, &mut outputs).unwrap();
        assert_outputs_eq(&outputs, &want_b, &format!("{name} (into, run 2)"));
        rt.execute_into(&art, &in_a, &mut outputs).unwrap();
        assert_outputs_eq(&outputs, &want_a, &format!("{name} (into, run 3)"));
    }
}

/// Train `steps` steps and return (losses, params, per-layer assignment
/// tables, per-layer whitened codebooks).
#[allow(clippy::type_complexity)]
fn vq_trajectory(
    model: &str,
    pipelined: bool,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<u32>>, Vec<Vec<f32>>) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds, model, "", NodeStrategy::Nodes, 7).unwrap();
    tr.set_pipelined(pipelined);
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(tr.train_step(&mut rt).unwrap());
    }
    let params = tr.params.iter().map(|p| p.f.clone()).collect();
    let assign = tr.vq.layers.iter().map(|l| l.assign.clone()).collect();
    let cww = tr
        .vq
        .layers
        .iter()
        .map(|l| l.branches.iter().flat_map(|b| b.cww.iter().copied()).collect())
        .collect();
    (losses, params, assign, cww)
}

#[test]
fn pipelined_vq_assembly_matches_serial_trajectory() {
    // Double-buffered prep must be invisible: same seeds → bit-identical
    // losses, parameters, assignment tables and codebooks.  One fixed and
    // one learnable backbone cover both sketch families (the txf leg also
    // exercises cnt_out assembly and the winsorized VQ update in place).
    for model in ["gcn", "txf"] {
        let serial = vq_trajectory(model, false, 6);
        let piped = vq_trajectory(model, true, 6);
        let sl: Vec<u32> = serial.0.iter().map(|x| x.to_bits()).collect();
        let pl: Vec<u32> = piped.0.iter().map(|x| x.to_bits()).collect();
        assert_eq!(sl, pl, "{model}: per-step losses diverged");
        assert_eq!(serial.2, piped.2, "{model}: assignment tables diverged");
        for (i, (s, p)) in serial.1.iter().zip(&piped.1).enumerate() {
            let sb: Vec<u32> = s.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = p.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "{model}: param {i} diverged");
        }
        for (l, (s, p)) in serial.3.iter().zip(&piped.3).enumerate() {
            let sb: Vec<u32> = s.iter().map(|x: &f32| x.to_bits()).collect();
            let pb: Vec<u32> = p.iter().map(|x: &f32| x.to_bits()).collect();
            assert_eq!(sb, pb, "{model}: layer {l} codebook diverged");
        }
    }
}

#[test]
fn mid_run_pipeline_toggle_matches_serial_trajectory() {
    // Toggling the overlapped prep on and off BETWEEN steps must be
    // invisible too: a prefetched batch pending at the moment of a
    // toggle-off is consumed (not dropped and resampled), and a toggle-on
    // resumes prefetching from the same rng schedule.  This pins the
    // `prefetched.take()` / `rng.fork(steps)` handoff that a mid-run
    // `set_pipelined` relies on.
    let serial = vq_trajectory("gcn", false, 6);
    let toggled = {
        let man = builtin();
        let mut rt = Runtime::native();
        let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
        let mut tr =
            VqTrainer::new(&mut rt, &man, ds, "gcn", "", NodeStrategy::Nodes, 7).unwrap();
        let mut losses = Vec::new();
        for (step, on) in [true, true, false, false, true, false].iter().enumerate() {
            tr.set_pipelined(*on);
            assert_eq!(tr.pipelined(), *on, "toggle at step {step} did not stick");
            losses.push(tr.train_step(&mut rt).unwrap());
        }
        losses
    };
    assert_eq!(
        serial.0.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
        toggled.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
        "mid-run pipeline toggles changed the trajectory"
    );
}

#[test]
fn link_task_trainers_never_pipeline() {
    // Link tasks draw negative pairs from the trainer rng on both the
    // train and evaluate paths, so the overlapped prefetch (which captures
    // `&mut rng`) would reorder draws whenever evaluation interleaves with
    // training.  Both trainers must refuse pipelining on link datasets —
    // at construction AND against an explicit set_pipelined(true).
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["collab_sim"], 42));
    let mut vq =
        VqTrainer::new(&mut rt, &man, ds.clone(), "sage", "", NodeStrategy::Nodes, 7).unwrap();
    assert!(!vq.pipelined(), "VqTrainer pipelined on a link task at construction");
    vq.set_pipelined(true);
    assert!(!vq.pipelined(), "VqTrainer accepted set_pipelined(true) on a link task");

    let mut ed =
        EdgeTrainer::new(&mut rt, &man, ds, "gcn", Baseline::FullGraph, 11).unwrap();
    assert!(!ed.pipelined(), "EdgeTrainer pipelined on a link task at construction");
    ed.set_pipelined(true);
    assert!(!ed.pipelined(), "EdgeTrainer accepted set_pipelined(true) on a link task");

    // node tasks keep the default-on behaviour (the property the link
    // gate must not regress)
    let tiny = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let nd = VqTrainer::new(&mut rt, &man, tiny, "gcn", "", NodeStrategy::Nodes, 7).unwrap();
    assert!(nd.pipelined(), "node-task trainer should pipeline by default");
}

fn edge_trajectory(kind: Baseline, dataset: &str, pipelined: bool, steps: usize) -> Vec<u32> {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets[dataset], 42));
    let mut tr = EdgeTrainer::new(&mut rt, &man, ds, "gcn", kind, 11).unwrap();
    tr.set_pipelined(pipelined);
    let mut bits = Vec::new();
    for _ in 0..steps {
        bits.push(tr.train_step(&mut rt).unwrap().to_bits());
    }
    for p in &tr.params {
        bits.extend(p.f.iter().map(|x| x.to_bits()));
    }
    bits
}

#[test]
fn pipelined_edge_assembly_matches_serial_trajectory() {
    // FullGraph exercises the overlapped prep thread itself; ClusterGcn
    // additionally couples prefetch to the trainer RNG stream (shuffled
    // cluster groups), pinning the draw-order argument in the module docs.
    assert_eq!(
        edge_trajectory(Baseline::FullGraph, "tiny_sim", false, 3),
        edge_trajectory(Baseline::FullGraph, "tiny_sim", true, 3),
        "full-graph edge trajectory diverged under pipelining"
    );
    assert_eq!(
        edge_trajectory(Baseline::ClusterGcn, "arxiv_sim", false, 2),
        edge_trajectory(Baseline::ClusterGcn, "arxiv_sim", true, 2),
        "cluster-gcn edge trajectory diverged under pipelining"
    );
}

#[test]
fn trainer_steps_are_reproducible_through_reused_sessions() {
    // Two identically-seeded trainers (both pipelined, the default) must
    // walk the same trajectory — the session/arena reuse adds no hidden
    // state to training.  Covers all four backbones cheaply.
    for model in ["gcn", "sage", "gat", "txf"] {
        let a = vq_trajectory(model, true, 3);
        let b = vq_trajectory(model, true, 3);
        assert_eq!(
            a.0.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            b.0.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            "{model}: losses not reproducible"
        );
        assert_eq!(a.2, b.2, "{model}: assignment tables not reproducible");
    }
}
