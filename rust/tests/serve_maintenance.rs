//! Online admitted-graph maintenance through the `ServeEngine` facade:
//! eviction (LRU cap + TTL), the codebook-drift signal, and the
//! drift-gated EMA refresh.
//!
//! Contracts under test:
//!
//! 1. **Typed knobs** — maintenance misconfiguration (zero cap, zero TTL,
//!    out-of-range drift threshold / refresh gamma) is a typed
//!    `ServeError` at build time, never a panic.
//! 2. **LRU cap** — driving admissions past `max_admitted` evicts
//!    least-recently-served-first with monotone, never-reissued ids;
//!    evicted ids are refused with the typed unknown-id error (as query
//!    targets AND link endpoints); the compacted tables cost no more than
//!    at the cap; frozen-node answers stay bit-identical through all the
//!    churn.
//! 3. **TTL** — nodes untouched past the TTL are evicted by `maintain`,
//!    and the id sequence continues past them.
//! 4. **Drift + refresh** — the drift metric is exactly zero when served
//!    traffic matches the frozen reference, rises on out-of-distribution
//!    admissions (alert counted once per excursion, edge-triggered at
//!    flush), and the EMA refresh reduces it.
//! 5. **VQS3 round-trip** — eviction state survives save → load:
//!    residents answer bit-identically, evicted ids stay refused, and a
//!    fresh admission continues the id sequence past the evictions.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (CI backbone matrix).

mod common;

use std::rc::Rc;
use std::time::{Duration, Instant};

use common::{builtin, model_enabled};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{Answer, Request, Served, ServeEngine, ServeError, ServingModel};

fn trained(model: &str, steps: usize, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
            .unwrap();
    for _ in 0..steps {
        tr.train_step(&mut rt).unwrap();
    }
    (rt, man, ds, tr)
}

fn answers(served: &[Served]) -> Vec<Answer> {
    served.iter().map(|s| s.answer.clone()).collect()
}

#[test]
fn maintenance_misconfiguration_is_typed_not_a_panic() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 1);
    let freeze = |rt: &mut Runtime| ServingModel::freeze(rt, &man, &tr).unwrap();

    let err = ServeEngine::builder()
        .model("gcn", freeze(&mut rt))
        .max_admitted(0)
        .build(Runtime::native())
        .unwrap_err();
    assert_eq!(err, ServeError::AdmitCapTooSmall(0));

    let err = ServeEngine::builder()
        .model("gcn", freeze(&mut rt))
        .admit_ttl(Duration::ZERO)
        .build(Runtime::native())
        .unwrap_err();
    assert_eq!(err, ServeError::ZeroAdmitTtl);

    for bad in [0.0f32, -0.5, 1.5, f32::NAN] {
        let err = ServeEngine::builder()
            .model("gcn", freeze(&mut rt))
            .drift_threshold(bad)
            .build(Runtime::native())
            .unwrap_err();
        assert_eq!(err, ServeError::BadDriftThreshold, "threshold {bad} must be refused");
    }
    for bad in [1.0f32, -0.1, 2.0, f32::NAN] {
        let err = ServeEngine::builder()
            .model("gcn", freeze(&mut rt))
            .refresh_gamma(bad)
            .build(Runtime::native())
            .unwrap_err();
        assert_eq!(err, ServeError::BadRefreshGamma, "gamma {bad} must be refused");
    }
    for e in [
        ServeError::AdmitCapTooSmall(0),
        ServeError::ZeroAdmitTtl,
        ServeError::BadDriftThreshold,
        ServeError::BadRefreshGamma,
    ] {
        assert!(!e.to_string().is_empty(), "{e:?} renders a message");
    }

    // a maintained configuration builds; the knobs echo through accessors
    let mut eng = ServeEngine::builder()
        .model("gcn", freeze(&mut rt))
        .max_admitted(8)
        .admit_ttl(Duration::from_secs(60))
        .drift_threshold(0.25)
        .refresh_gamma(0.5)
        .build(rt)
        .unwrap();
    assert_eq!(eng.max_admitted(), Some(8));
    assert_eq!(eng.admit_ttl(), Some(Duration::from_secs(60)));
    assert_eq!(eng.drift_threshold(), 0.25);
    assert_eq!(eng.refresh_gamma(), 0.5);
    // nothing admitted: a maintenance pass has nothing to do
    assert_eq!(eng.maintain("gcn").unwrap(), 0);
    assert_eq!(eng.stats("gcn").unwrap().evictions, 0);
    assert!(eng.maintain("nope").is_err(), "unknown model is an error");
}

#[test]
fn lru_cap_evicts_oldest_and_preserves_frozen_answers() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 3, 7);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let n = ds.n() as u32;
    let mut eng =
        ServeEngine::builder().model("gcn", sm).max_admitted(4).build(rt).unwrap();

    let frozen_q: Vec<Request> = (0..6).map(|i| Request::Node(i * 7 % n)).collect();
    for &r in &frozen_q {
        eng.submit("gcn", r).unwrap();
    }
    let before = answers(&eng.drain().unwrap());
    let mem0 = eng.model("gcn").unwrap().cache().memory_bytes();

    // admissions 1..=4 fill to the cap; every one past it evicts the LRU
    // resident (admission order == touch order here, ties broken by id)
    let feat = ds.feature_row(3).to_vec();
    let mut ids = Vec::new();
    let mut mem_at_cap = 0u64;
    for i in 0..10u32 {
        ids.push(eng.admit("gcn", &feat, &[i % n]).unwrap());
        if ids.len() == 4 {
            mem_at_cap = eng.model("gcn").unwrap().cache().memory_bytes();
        }
    }
    assert_eq!(ids, (n..n + 10).collect::<Vec<u32>>(), "ids are monotone, never reused");
    assert_eq!(eng.stats("gcn").unwrap().evictions, 6);
    assert_eq!(eng.model("gcn").unwrap().total_nodes(), ds.n() + 4);

    // eviction compacts: the resident tables cost exactly what they cost
    // when the cap was first reached, not 10 nodes' worth of tombstones
    let mem_now = eng.model("gcn").unwrap().cache().memory_bytes();
    assert_eq!(mem_now, mem_at_cap, "eviction must shrink the tables");
    assert!(mem_now > mem0, "residents still cost something");

    // evicted ids are refused with the typed unknown-id error — as query
    // targets and as link endpoints
    let err = eng.submit("gcn", Request::Node(n)).unwrap_err();
    assert_eq!(
        err,
        ServeError::InvalidNode { model: "gcn".into(), id: n, total: ds.n() + 4 }
    );
    assert!(matches!(
        eng.submit("gcn", Request::Link(0, n + 2)),
        Err(ServeError::InvalidNode { .. })
    ));
    // the 4 youngest admissions are resident and still serve
    for &id in &ids[6..] {
        eng.submit("gcn", Request::Node(id)).unwrap();
    }
    assert_eq!(eng.drain().unwrap().len(), 4);

    // frozen-node answers are bit-identical through admit + evict churn
    for &r in &frozen_q {
        eng.submit("gcn", r).unwrap();
    }
    let after = answers(&eng.drain().unwrap());
    assert_eq!(before, after, "maintenance perturbed frozen answers");
}

#[test]
fn ttl_expiry_evicts_via_maintain() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 2, 5);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let n = ds.n() as u32;
    let ttl = Duration::from_millis(25);
    let mut eng = ServeEngine::builder().model("gcn", sm).admit_ttl(ttl).build(rt).unwrap();

    let feat = ds.feature_row(0).to_vec();
    let admitted_at = Instant::now();
    for i in 0..3u32 {
        eng.admit("gcn", &feat, &[i]).unwrap();
    }
    let last_admit = Instant::now();
    assert_eq!(eng.model("gcn").unwrap().total_nodes(), ds.n() + 3);

    // inside the TTL nothing expires (only asserted when provably inside)
    let early = eng.maintain("gcn").unwrap();
    if admitted_at.elapsed() < ttl {
        assert_eq!(early, 0, "nothing may expire before the TTL");
    }

    // outlive the TTL: every admission is older than `ttl` once
    // `last_admit` is — bounded wait on the clock, not a sleep
    while last_admit.elapsed() <= ttl {
        std::thread::yield_now();
    }
    let evicted = eng.maintain("gcn").unwrap();
    assert_eq!(evicted + early, 3, "all admissions expire");
    assert_eq!(eng.stats("gcn").unwrap().evictions, 3);
    assert_eq!(eng.model("gcn").unwrap().total_nodes(), ds.n());

    // expired ids stay dead; the id sequence continues past them
    assert!(matches!(
        eng.submit("gcn", Request::Node(n)),
        Err(ServeError::InvalidNode { .. })
    ));
    assert_eq!(
        eng.admit("gcn", &feat, &[]).unwrap(),
        n + 3,
        "ids are never reissued after TTL eviction"
    );
}

#[test]
fn drift_signal_alerts_once_and_refresh_reduces_it() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 3, 9);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let n = ds.n() as u32;
    // gamma near 1: the refresh barely moves codewords, so the drift drop
    // asserted below comes from the guaranteed part of its contract — the
    // observation histogram is re-scored over the RETAINED ring, from
    // which the far burst has aged out — not from chasing the burst
    let mut eng = ServeEngine::builder()
        .model("gcn", sm)
        .drift_threshold(0.1)
        .refresh_gamma(0.99)
        .build(rt)
        .unwrap();
    let serve_all = |eng: &mut ServeEngine| {
        for v in 0..n {
            eng.submit("gcn", Request::Node(v)).unwrap();
        }
        eng.drain().unwrap();
    };

    // serve every frozen node exactly once: the observed layer-0 histogram
    // then EQUALS the reference frozen at export (same rows, same nearest-
    // codeword distances, same binning), so the drift metric is exactly 0
    serve_all(&mut eng);
    let d0 = eng.drift("gcn").unwrap();
    assert_eq!(d0, 0.0, "in-reference traffic must read as zero drift");
    assert_eq!(eng.stats("gcn").unwrap().drift_alerts, 0);
    // below the threshold, refresh refuses to wander
    assert!(!eng.refresh("gcn").unwrap(), "healthy codebooks must not move");

    // an out-of-distribution admission burst: rows far off every codeword
    // land in the histogram's saturation bin and drag the TV distance up
    let far: Vec<f32> = ds.feature_row(0).iter().map(|x| x + 1000.0).collect();
    for i in 0..n {
        eng.admit("gcn", &far, &[i % n]).unwrap();
    }
    let d_burst = eng.drift("gcn").unwrap();
    assert!(
        d_burst > eng.drift_threshold(),
        "the far burst must trip the threshold (drift {d_burst})"
    );

    // the excursion is counted ONCE, at flush time (edge-triggered)
    eng.submit("gcn", Request::Node(0)).unwrap();
    eng.drain().unwrap();
    assert_eq!(eng.stats("gcn").unwrap().drift_alerts, 1);
    eng.submit("gcn", Request::Node(1)).unwrap();
    eng.drain().unwrap();
    assert_eq!(
        eng.stats("gcn").unwrap().drift_alerts,
        1,
        "a sustained excursion counts once, not once per flush"
    );

    // the burst passes; in-distribution traffic resumes.  Two full frozen
    // passes (512 rows) overwrite the whole retained ring, but the
    // lifetime observation histogram still carries the burst's saturation
    // mass — the metric stays above threshold
    serve_all(&mut eng);
    serve_all(&mut eng);
    let d1 = eng.drift("gcn").unwrap();
    assert!(d1 > eng.drift_threshold(), "burst mass must persist in the metric ({d1})");

    // refresh: codewords nudged by 1%, observation re-scored over the
    // retained (now in-distribution) ring — the burst ages out of the
    // metric and the drift drops
    assert!(eng.refresh("gcn").unwrap(), "drift-gated refresh must run");
    let d2 = eng.drift("gcn").unwrap();
    assert!(d2 < d1, "EMA refresh must reduce drift ({d1} -> {d2})");

    // the refreshed model still serves (template rebuild reached the pool)
    eng.submit("gcn", Request::Node(0)).unwrap();
    eng.submit("gcn", Request::Node(n)).unwrap(); // first admitted node
    let served = eng.drain().unwrap();
    assert_eq!(served.len(), 2);
    for s in &served {
        match &s.answer {
            Answer::Scores(row) => assert!(row.iter().all(|x| x.is_finite())),
            other => panic!("node query answered with {other:?}"),
        }
    }
}

#[test]
fn eviction_state_round_trips_through_vqs3() {
    let dir = std::env::temp_dir().join("vqgnn_serve_maintenance_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for model in ["gcn", "sage", "gat", "txf"] {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, tr) = trained(model, 2, 13);
        let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let n = ds.n() as u32;
        let mut eng =
            ServeEngine::builder().model(model, sm).max_admitted(2).build(rt).unwrap();

        let feat = ds.feature_row(1).to_vec();
        for i in 0..5u32 {
            eng.admit(model, &feat, &[i]).unwrap();
        }
        assert_eq!(eng.stats(model).unwrap().evictions, 3);
        // residents: the two youngest ids
        eng.submit(model, Request::Node(n + 3)).unwrap();
        eng.submit(model, Request::Node(n + 4)).unwrap();
        let live = answers(&eng.drain().unwrap());

        let path = dir.join(format!("{model}.v3.bin"));
        eng.model(model).unwrap().save(&path).unwrap();
        let sm2 =
            ServingModel::load(eng.runtime_mut(), &man, ds.clone(), model, &path).unwrap();
        assert_eq!(sm2.total_nodes(), ds.n() + 2);
        eng.add_model("reloaded", sm2).unwrap();

        // evicted ids stay refused across the reload
        assert!(matches!(
            eng.submit("reloaded", Request::Node(n)),
            Err(ServeError::InvalidNode { .. })
        ));
        // residents answer bit-identically
        eng.submit("reloaded", Request::Node(n + 3)).unwrap();
        eng.submit("reloaded", Request::Node(n + 4)).unwrap();
        let live2 = answers(&eng.drain().unwrap());
        assert_eq!(live, live2, "{model}: resident answers drifted across VQS3 reload");
        // and a fresh admission continues the id sequence past the evictions
        assert_eq!(
            eng.admit("reloaded", &feat, &[0]).unwrap(),
            n + 5,
            "{model}: the id high-water mark survives the round-trip"
        );
    }
}
