//! `ServeEngine` facade contracts: validated construction, the
//! load-shedding policy's exact refusal shape, multi-model routing with
//! one global ticket sequence, and the pledge that the `#[deprecated]`
//! `MicroBatcher::{flush,drain}` shims answer bit-identically to the
//! facade (they delegate to the same body — this test pins that).

mod common;

use std::rc::Rc;
use std::time::Duration;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{
    Answer, MicroBatcher, Request, ServeEngine, ServeError, ServingModel,
};

fn trained(model: &str, steps: usize, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
            .unwrap();
    for _ in 0..steps {
        tr.train_step(&mut rt).unwrap();
    }
    (rt, man, ds, tr)
}

#[test]
fn builder_misconfiguration_is_typed_not_a_panic() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 1);
    let freeze = |rt: &mut Runtime| ServingModel::freeze(rt, &man, &tr).unwrap();

    let err = ServeEngine::builder().build(Runtime::native()).unwrap_err();
    assert_eq!(err, ServeError::NoModels);

    let sm = freeze(&mut rt);
    let err = ServeEngine::builder()
        .model("gcn", sm)
        .threads(0)
        .build(Runtime::native())
        .unwrap_err();
    assert_eq!(err, ServeError::ZeroWorkers);

    let sm = freeze(&mut rt);
    let err = ServeEngine::builder()
        .model("gcn", sm)
        .queue_cap(1)
        .build(Runtime::native())
        .unwrap_err();
    assert_eq!(err, ServeError::QueueCapTooSmall(1));

    let (a, b) = (freeze(&mut rt), freeze(&mut rt));
    let err = ServeEngine::builder()
        .model("gcn", a)
        .model("gcn", b)
        .build(Runtime::native())
        .unwrap_err();
    assert_eq!(err, ServeError::DuplicateModel("gcn".into()));

    for e in [
        ServeError::NoModels,
        ServeError::ZeroWorkers,
        ServeError::QueueCapTooSmall(1),
        ServeError::DuplicateModel("gcn".into()),
    ] {
        assert!(!e.to_string().is_empty(), "{e:?} renders a message");
    }

    // a well-formed configuration still builds and serves
    let sm = freeze(&mut rt);
    let mut eng = ServeEngine::builder()
        .model("gcn", sm)
        .threads(2)
        .deadline(Duration::from_millis(5))
        .queue_cap(256)
        .build(rt)
        .unwrap();
    assert_eq!(eng.threads(), 2);
    assert_eq!(eng.deadline(), Some(Duration::from_millis(5)));
    assert_eq!(eng.queue_cap(), Some(256));
    eng.submit("gcn", Request::Node(0)).unwrap();
    assert_eq!(eng.drain().unwrap().len(), 1);
}

#[test]
#[allow(deprecated)]
fn deprecated_shims_answer_bit_identical_to_facade() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 3, 9);
    // two freezes of one trainer are the same model
    let mut sm_shim = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let sm_facade = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let b = sm_shim.batch_size();
    sm_shim.set_threads(2);

    let reqs: Vec<Request> = (0..b + b / 2)
        .map(|i| {
            if i % 7 == 3 {
                Request::Link((i % ds.n()) as u32, ((i * 3) % ds.n()) as u32)
            } else {
                Request::Node(((i * 5) % ds.n()) as u32)
            }
        })
        .collect();

    // old call shape: direct MicroBatcher against &Runtime + &mut model
    let mut mb = MicroBatcher::new();
    for &r in &reqs {
        mb.submit(r);
    }
    let mut old = mb.flush(&rt, &mut sm_shim).unwrap();
    old.extend(mb.drain(&rt, &mut sm_shim).unwrap());
    let old: Vec<Answer> = old.into_iter().map(|s| s.answer).collect();

    // facade call shape: same queries through the engine
    let mut eng = ServeEngine::builder()
        .model("gcn", sm_facade)
        .threads(2)
        .build(rt)
        .unwrap();
    for &r in &reqs {
        eng.submit("gcn", r).unwrap();
    }
    let mut new = eng.poll().unwrap();
    new.extend(eng.drain().unwrap());
    new.sort_by_key(|s| s.id);
    let new: Vec<Answer> = new.into_iter().map(|s| s.answer).collect();

    assert_eq!(old, new, "deprecated shim diverged from ServeEngine");
}

#[test]
fn bounded_queue_sheds_with_exact_refusal_shape() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 3);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let other = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let b = sm.batch_size();
    assert!(b >= 4);
    let mut eng = ServeEngine::builder()
        .model("gcn", sm)
        .model("other", other)
        .queue_cap(b)
        .build(rt)
        .unwrap();

    // fill gcn's queue exactly to capacity
    for i in 0..b {
        eng.submit("gcn", Request::Node((i % 8) as u32)).unwrap();
    }
    let err = eng.submit("gcn", Request::Node(0)).unwrap_err();
    assert_eq!(
        err,
        ServeError::Shed { model: "gcn".into(), pending_slots: b, cap: b }
    );
    assert!(!err.to_string().is_empty());
    // the cap is PER MODEL: the sibling queue still admits
    eng.submit("other", Request::Node(0)).unwrap();

    // shedding is in slots, not requests: a link (2 slots) is refused at
    // b-1 pending where a node (1 slot) still fits
    let served = eng.drain().unwrap();
    assert_eq!(served.len(), b + 1, "drain recovers capacity");
    for _ in 0..(b - 1) {
        eng.submit("gcn", Request::Node(1)).unwrap();
    }
    let err = eng.submit("gcn", Request::Link(1, 2)).unwrap_err();
    assert_eq!(
        err,
        ServeError::Shed { model: "gcn".into(), pending_slots: b - 1, cap: b }
    );
    eng.submit("gcn", Request::Node(2)).unwrap();
    assert_eq!(eng.drain().unwrap().len(), b);
}

#[test]
fn unknown_model_is_a_typed_routing_error() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 2);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let dup = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let mut eng = ServeEngine::builder().model("gcn", sm).build(rt).unwrap();
    assert_eq!(
        eng.submit("nope", Request::Node(0)).unwrap_err(),
        ServeError::UnknownModel("nope".into())
    );
    assert!(eng.stats("nope").is_none());
    assert!(eng.model("nope").is_none());
    assert!(eng.admit("nope", &[0.0; 4], &[]).is_err());
    assert_eq!(
        eng.add_model("gcn", dup).unwrap_err(),
        ServeError::DuplicateModel("gcn".into())
    );
    assert_eq!(eng.models(), vec!["gcn"]);
}

#[test]
fn multi_model_routing_interleaves_one_ticket_sequence() {
    if !(model_enabled("gcn") && model_enabled("sage")) {
        return;
    }
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr_g =
        VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "", NodeStrategy::Nodes, 7).unwrap();
    let mut tr_s =
        VqTrainer::new(&mut rt, &man, ds.clone(), "sage", "", NodeStrategy::Nodes, 8).unwrap();
    for _ in 0..2 {
        tr_g.train_step(&mut rt).unwrap();
        tr_s.train_step(&mut rt).unwrap();
    }
    let sm_g = ServingModel::freeze(&mut rt, &man, &tr_g).unwrap();
    let sm_s = ServingModel::freeze(&mut rt, &man, &tr_s).unwrap();
    let c = sm_g.out_dim();
    assert_eq!(c, sm_s.out_dim());

    let queries: Vec<u32> = (0..70).map(|i| (i * 11 % ds.n()) as u32).collect();
    let mut eng = ServeEngine::builder()
        .model("gcn", sm_g)
        .model("sage", sm_s)
        .build(rt)
        .unwrap();
    for &v in &queries {
        assert_eq!(eng.submit("gcn", Request::Node(v)).unwrap() % 2, 0);
        assert_eq!(eng.submit("sage", Request::Node(v)).unwrap() % 2, 1);
    }
    let served = eng.drain().unwrap();
    assert_eq!(served.len(), 2 * queries.len());
    let want_g = tr_g.infer_nodes(eng.runtime_mut(), &queries).unwrap();
    let want_s = tr_s.infer_nodes(eng.runtime_mut(), &queries).unwrap();
    for (i, &v) in queries.iter().enumerate() {
        let (g, s) = (&served[2 * i], &served[2 * i + 1]);
        assert_eq!(g.id, 2 * i, "global tickets interleave the two models");
        assert_eq!(s.id, 2 * i + 1);
        assert_eq!(
            g.answer,
            Answer::Scores(want_g[i * c..(i + 1) * c].to_vec()),
            "gcn row for node {v} diverged"
        );
        assert_eq!(
            s.answer,
            Answer::Scores(want_s[i * c..(i + 1) * c].to_vec()),
            "sage row for node {v} diverged"
        );
    }
}
