//! SIMD/scalar parity properties (tier 2).
//!
//! Every dispatched primitive in `util::simd` is pitted against its
//! scalar twin across randomized shapes, with the awkward cases forced:
//! widths ≢ 0 mod the widest lane count (remainder loops), lengths below
//! one vector, and unaligned slice starts (`&buf[1..]` — the kernels use
//! unaligned loads, so alignment must never matter).  The exactness
//! contract is per-primitive:
//!
//! - **bit-exact**: `scale`, `add_assign`, `whiten_row`, `lerp`,
//!   `scale_into`, `scale2_into` keep scalar per-element arithmetic
//!   (mul/add only, no FMA), so both paths must agree bitwise;
//! - **integer-exact**: `dot_i8` accumulates in i32 — associativity is
//!   exact, so lane order cannot change the sum;
//! - **tolerance**: `dot`, `sum_sq`, `axpy` reassociate across lanes and
//!   may contract to FMA — parity holds to a relative tolerance only.
//!
//! On a runner without AVX2/NEON (or under `VQGNN_SIMD=0`) the dispatched
//! fns ARE the scalar twins and every assertion is trivially tight; CI
//! runs the suite both ways.
//!
//! The two-stage FINDNEAREST prune carries a stronger contract — the
//! i8 first pass is a sound bound, so `assign_pruned` must reproduce
//! `assign_blocked` bit-for-bit (same process, same dispatch) for every
//! tested top-m, including m=1. That recall property is checked here at
//! integration scale on top of the unit cases in `vq::kernels`.

use vq_gnn::prop_assert;
use vq_gnn::util::prop;
use vq_gnn::util::rng::Rng;
use vq_gnn::util::simd;
use vq_gnn::vq::kernels;

/// Shape schedule covering sub-lane, exact-lane and remainder widths for
/// both 8-lane (AVX2) and 4-lane (NEON) kernels, plus the i8 kernel's
/// 16/8-lane strides.
const LENS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 23, 31, 32, 33, 63, 100, 257];

fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gauss_f32()).collect()
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    let scale = a.abs().max(b.abs()).max(1.0);
    (a - b).abs() <= tol * scale
}

#[test]
fn reductions_match_scalar_within_tolerance_over_shapes() {
    prop::check("simd reductions vs scalar", 24, |rng, _case| {
        for &n in LENS {
            // over-allocate and slice from offset 1 so the vector body
            // starts unaligned
            let a = fill(rng, n + 1);
            let b = fill(rng, n + 1);
            let (a, b) = (&a[1..], &b[1..]);
            let d = simd::dot(a, b);
            let ds = simd::scalar::dot(a, b);
            prop_assert!(rel_close(d, ds, 1e-4), "dot n={n}: {d} vs {ds}");
            let s = simd::sum_sq(a);
            let ss = simd::scalar::sum_sq(a);
            prop_assert!(rel_close(s, ss, 1e-4), "sum_sq n={n}: {s} vs {ss}");
        }
        Ok(())
    });
}

#[test]
fn axpy_matches_scalar_within_tolerance_over_shapes() {
    prop::check("simd axpy vs scalar", 24, |rng, _case| {
        for &n in LENS {
            let x = fill(rng, n + 1);
            let y0 = fill(rng, n + 1);
            let alpha = rng.gauss_f32();
            let mut y_v = y0.clone();
            let mut y_s = y0.clone();
            simd::axpy(&mut y_v[1..], alpha, &x[1..]);
            simd::scalar::axpy(&mut y_s[1..], alpha, &x[1..]);
            for i in 1..n + 1 {
                prop_assert!(
                    rel_close(y_v[i], y_s[i], 1e-5),
                    "axpy n={n} i={i}: {} vs {}",
                    y_v[i],
                    y_s[i]
                );
            }
            // the untouched prefix must stay untouched
            prop_assert!(y_v[0].to_bits() == y0[0].to_bits(), "axpy wrote before the slice");
        }
        Ok(())
    });
}

#[test]
fn elementwise_primitives_match_scalar_bitwise_over_shapes() {
    prop::check("simd elementwise vs scalar (bitwise)", 24, |rng, _case| {
        for &n in LENS {
            let x = fill(rng, n + 1);
            let y = fill(rng, n + 1);
            let mean = fill(rng, n + 1);
            let inv: Vec<f32> = (0..n + 1).map(|_| 0.5 + rng.f32()).collect();
            let (a, b2) = (rng.gauss_f32(), rng.gauss_f32());
            let beta = rng.f32();

            let mut v = y.clone();
            let mut s = y.clone();
            simd::scale(&mut v[1..], a);
            simd::scalar::scale(&mut s[1..], a);
            prop_assert!(bits(&v) == bits(&s), "scale n={n} diverged bitwise");

            let (mut v, mut s) = (y.clone(), y.clone());
            simd::add_assign(&mut v[1..], &x[1..]);
            simd::scalar::add_assign(&mut s[1..], &x[1..]);
            prop_assert!(bits(&v) == bits(&s), "add_assign n={n} diverged bitwise");

            let (mut v, mut s) = (y.clone(), y.clone());
            simd::whiten_row(&mut v[1..], &x[1..], &mean[1..], &inv[1..]);
            simd::scalar::whiten_row(&mut s[1..], &x[1..], &mean[1..], &inv[1..]);
            prop_assert!(bits(&v) == bits(&s), "whiten_row n={n} diverged bitwise");

            let (mut v, mut s) = (y.clone(), y.clone());
            simd::lerp(&mut v[1..], &x[1..], beta);
            simd::scalar::lerp(&mut s[1..], &x[1..], beta);
            prop_assert!(bits(&v) == bits(&s), "lerp n={n} diverged bitwise");

            let (mut v, mut s) = (y.clone(), y.clone());
            simd::scale_into(&mut v[1..], a, &x[1..]);
            simd::scalar::scale_into(&mut s[1..], a, &x[1..]);
            prop_assert!(bits(&v) == bits(&s), "scale_into n={n} diverged bitwise");

            let (mut v, mut s) = (vec![0.0; n + 1], vec![0.0; n + 1]);
            simd::scale2_into(&mut v[1..], a, &x[1..], b2, &mean[1..]);
            simd::scalar::scale2_into(&mut s[1..], a, &x[1..], b2, &mean[1..]);
            prop_assert!(bits(&v) == bits(&s), "scale2_into n={n} diverged bitwise");
        }
        Ok(())
    });
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn dot_i8_matches_scalar_exactly_over_shapes() {
    prop::check("simd dot_i8 vs scalar (exact)", 24, |rng, _case| {
        for &n in LENS {
            let a: Vec<i8> = (0..n + 1).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n + 1).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let got = simd::dot_i8(&a[1..], &b[1..]);
            let want = simd::scalar::dot_i8(&a[1..], &b[1..]);
            prop_assert!(got == want, "dot_i8 n={n}: {got} vs {want}");
        }
        Ok(())
    });
}

#[test]
fn parse_resolves_env_and_capabilities() {
    use vq_gnn::util::simd::Simd;
    // every documented "off" spelling forces scalar regardless of hardware
    for off in ["0", "off", "false", "scalar", " OFF ", "False"] {
        assert_eq!(simd::parse(Some(off), true, false), Simd::Scalar, "{off:?}");
        assert_eq!(simd::parse(Some(off), false, true), Simd::Scalar, "{off:?}");
    }
    // unset or any other value defers to hardware capability
    for env in [None, Some("1"), Some("on"), Some("auto")] {
        assert_eq!(simd::parse(env, true, false), Simd::Avx2);
        assert_eq!(simd::parse(env, false, true), Simd::Neon);
        assert_eq!(simd::parse(env, false, false), Simd::Scalar);
    }
    // the resolved dispatch is process-stable and names itself
    assert_eq!(simd::active(), simd::active());
    assert!(["scalar", "avx2", "neon"].contains(&simd::name()));
}

/// The prune's recall contract at integration scale: for k well above
/// `PRUNE_MIN_K`, random whitened vectors and codewords, the two-stage
/// assignment must equal the exact blocked kernel bit-for-bit for every
/// top-m — the error bound guarantees the true argmin (and all its exact
/// ties) survives to the rescore, so this is equality, not tolerance.
#[test]
fn prune_recall_exact_across_top_m() {
    prop::check("assign_pruned == assign_blocked for all m", 8, |rng, case| {
        let k = kernels::PRUNE_MIN_K + rng.below(96);
        let fp = 3 + rng.below(34); // hits sub-lane and remainder widths
        let b = 48 + rng.below(160);
        let vw = fill(rng, b * fp);
        let mut cww = fill(rng, k * fp);
        // plant duplicates + a zero codeword so exact ties and zero
        // scales are exercised at this scale too
        if case % 2 == 0 && k >= 2 {
            let (lo, hi) = cww.split_at_mut(fp);
            hi[..fp].copy_from_slice(lo);
            for x in &mut cww[(k - 1) * fp..] {
                *x = 0.0;
            }
        }
        let mut want = vec![0i32; b];
        kernels::assign_blocked(&vw, fp, fp, &cww, k, fp, &mut want);
        let qcb = kernels::QuantCodebook::build(&cww, k, fp, fp);
        for m in [1usize, 4, kernels::PRUNE_TOP_M, k] {
            let mut got = vec![0i32; b];
            kernels::assign_pruned(&vw, fp, fp, &cww, fp, &qcb, m, &mut got);
            prop_assert!(
                got == want,
                "prune m={m} k={k} fp={fp} b={b}: assignment diverged from exact kernel"
            );
        }
        Ok(())
    });
}
