//! Observability contracts (tier-1 for the metrics layer):
//!
//! 1. **Quantile error bound** — for in-range samples (≥ 256 ns, below the
//!    saturation bucket) the histogram's nearest-rank bucket-midpoint
//!    quantile is within 25% relative error of the exact nearest-rank
//!    value from a full sort, at p50/p90/p99, across randomized sample
//!    sets (property test).
//! 2. **Merge = pooled** — folding per-worker histograms together
//!    (`Histogram::merge_into` and `HistSnapshot::merge` both) produces
//!    exactly the buckets/count/sum/max one shared histogram would have
//!    recorded (property test).
//! 3. **Saturation** — out-of-range samples land in the last bucket with
//!    exact counts and a finite quantile.
//! 4. **Metrics never perturb the data path** — the same request stream
//!    through the same frozen model answers bit-identically with a live
//!    registry attached vs. metrics-free, on every backbone, and the
//!    registry does observe the traffic (the scrape carries the serve
//!    families).  Honors the `VQGNN_MODEL` CI matrix filter.

mod common;

use std::rc::Rc;
use std::sync::Arc;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::obs::{HistSnapshot, Histogram, Registry, BUCKETS};
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{Answer, Request, Served, ServeEngine, ServingModel};
use vq_gnn::util::prop::check;
use vq_gnn::util::rng::Rng;

#[test]
fn quantile_estimates_stay_within_the_bucket_bound() {
    check("histogram_quantile_bound", 60, |rng, _| {
        let n = 1 + rng.below(400);
        let mut vals: Vec<u64> = (0..n)
            .map(|_| {
                // log-uniform octave in [2^8, 2^36): in-range by a wide
                // margin (saturation starts near 2^39), above bucket 0
                let e = (8 + rng.below(28)) as u32;
                (1u64 << e) + rng.below(1usize << e) as u64
            })
            .collect();
        let h = Histogram::new();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        for q in [0.5, 0.9, 0.99] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = vals[rank - 1] as f64;
            let est = s.quantile_ns(q) as f64;
            if (est - exact).abs() > 0.25 * exact {
                return Err(format!("q={q}: estimate {est} vs exact {exact} (n={n}, >25% off)"));
            }
        }
        Ok(())
    });
}

#[test]
fn merging_worker_histograms_equals_pooled_recording() {
    check("histogram_merge_pooled", 40, |rng, _| {
        let workers = 1 + rng.below(4);
        let pooled = Histogram::new();
        let merged = Histogram::new();
        let mut snap = HistSnapshot::default();
        for _ in 0..workers {
            let part = Histogram::new();
            for _ in 0..rng.below(200) {
                let v = rng.below(1usize << 40) as u64; // incl. saturation range
                part.record(v);
                pooled.record(v);
            }
            part.merge_into(&merged);
            snap.merge(&part.snapshot());
        }
        let want = pooled.snapshot();
        for got in [merged.snapshot(), snap] {
            if got.buckets != want.buckets {
                return Err("bucket counts differ from pooled recording".into());
            }
            if (got.count, got.sum_ns, got.max_ns) != (want.count, want.sum_ns, want.max_ns) {
                return Err(format!(
                    "exact fields differ: ({}, {}, {}) vs ({}, {}, {})",
                    got.count, got.sum_ns, got.max_ns, want.count, want.sum_ns, want.max_ns
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn saturation_bucket_captures_out_of_range_samples() {
    let h = Histogram::new();
    h.record(u64::MAX);
    h.record(1u64 << 62);
    let s = h.snapshot();
    assert_eq!(s.buckets[BUCKETS - 1], 2, "both land in the saturation bucket");
    assert_eq!(s.count, 2);
    assert_eq!(s.max_ns, u64::MAX, "max is exact even when bucketed");
    let q = s.quantile_ns(0.99);
    assert!(q > 0 && q < u64::MAX, "saturated quantile stays finite: {q}");
}

/// Mixed node/link stream with duplicates — same shape the concurrency
/// tests pin, small enough to keep all four backbones fast.
fn request_stream(n: usize, count: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            if i % 5 == 3 {
                Request::Link(rng.below(n) as u32, rng.below(n) as u32)
            } else {
                Request::Node(rng.below(n) as u32)
            }
        })
        .collect()
}

fn drain_sorted(eng: &mut ServeEngine, model: &str, reqs: &[Request]) -> Vec<Served> {
    for r in reqs {
        eng.submit(model, *r).unwrap();
    }
    let mut served = eng.drain().unwrap();
    served.sort_by_key(|s| s.id);
    served
}

#[test]
fn served_answers_are_byte_identical_with_metrics_on() {
    for model in ["gcn", "sage", "gat", "txf"] {
        if !model_enabled(model) {
            continue;
        }
        let man = builtin();
        let mut rt = Runtime::native();
        let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
        let mut tr =
            VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, 7).unwrap();
        for _ in 0..2 {
            tr.train_step(&mut rt).unwrap();
        }
        let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let reqs = request_stream(ds.n(), 150, 0x0B5E);

        // metrics-free reference pass
        let mut eng = ServeEngine::builder().model(model, sm).build(rt).unwrap();
        assert!(eng.registry().is_none());
        let base = drain_sorted(&mut eng, model, &reqs);

        // the SAME engine parts rebuilt behind a live registry
        let reg = Arc::new(Registry::new());
        let (rt, models) = eng.into_parts();
        let mut builder = ServeEngine::builder().metrics(reg.clone());
        for (name, m) in models {
            builder = builder.model(name, m);
        }
        let mut eng = builder.build(rt).unwrap();
        let inst = drain_sorted(&mut eng, model, &reqs);

        assert_eq!(base.len(), inst.len(), "{model}: served counts differ");
        for (a, b) in base.iter().zip(&inst) {
            assert_eq!(a.id, b.id, "{model}: answer order differs");
            match (&a.answer, &b.answer) {
                (Answer::Scores(x), Answer::Scores(y)) => {
                    assert!(
                        x.len() == y.len()
                            && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits()),
                        "{model}: req {} scores differ with metrics on",
                        a.id
                    );
                }
                (Answer::Link(x), Answer::Link(y)) => {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{model}: req {} link score differs with metrics on",
                        a.id
                    );
                }
                _ => panic!("{model}: req {} answer kind differs", a.id),
            }
        }

        // ... and the registry did observe the traffic: every documented
        // serve family is present, deterministically ordered
        let text = reg.render_prometheus();
        assert_eq!(text, reg.render_prometheus(), "{model}: scrape is byte-stable");
        for family in [
            "serve_requests_total",
            "serve_served_total",
            "serve_queue_wait_seconds",
            "serve_request_latency_seconds_count",
            "serve_batch_assembly_seconds",
            "serve_session_exec_seconds",
            "vq_codebook_perplexity_l0",
            "vq_dead_codes_l0",
            "serve_resident_admitted",
            "serve_cache_bytes",
        ] {
            assert!(text.contains(family), "{model}: scrape missing {family}:\n{text}");
        }
    }
}
