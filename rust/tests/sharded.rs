//! Sharded scale-out determinism, end to end.
//!
//! Contracts under test (see `shard` module docs for the why):
//!
//! 1. **Training bit-identity** — a `VqTrainer` with `set_shards(S)` walks
//!    the EXACT trajectory of the unsharded trainer at S ∈ {1, 2, 4}:
//!    parameters and the full VQ state (codebooks, EMA stats, assignment
//!    tables) compare bit-for-bit after every step, on all four backbones,
//!    with and without dead-code expiry.
//! 2. **Serving bit-identity** — a `ServeEngine` built with `.shards(S)`
//!    returns byte-identical answers AND byte-identical maintenance state
//!    (drift histogram, refresh ring) at S ∈ {1, 2, 4}.
//! 3. **Partial-merge determinism** — for random chunk-aligned split
//!    points, per-shard partials merged in global chunk order reproduce
//!    the whole-batch kernels bit-for-bit (the property the sharded EMA
//!    update rests on).
//! 4. **Partition-map round-trip** — a sharded trainer's `ShardPlan`
//!    survives checkpoint save → load; unsharded checkpoints load `None`.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (CI backbone matrix).

mod common;

use std::rc::Rc;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::{checkpoint, vq_trainer::VqTrainer};
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{Answer, Request, ServeEngine, Served, ServingModel};
use vq_gnn::shard::{chunk_range, ShardPlan};
use vq_gnn::util::rng::Rng;
use vq_gnn::vq::kernels;

const BACKBONES: [&str; 4] = ["gcn", "sage", "gat", "txf"];

fn fresh_trainer(model: &str, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let tr = VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
        .unwrap();
    (rt, man, ds, tr)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bit image of everything a training step mutates.
fn state_bits(tr: &VqTrainer) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = tr.params.iter().map(|p| bits(&p.f)).collect();
    for l in &tr.vq.layers {
        out.push(l.assign.clone());
        for br in &l.branches {
            out.push(bits(&br.cww));
            out.push(bits(&br.counts));
            out.push(bits(&br.sums));
            out.push(bits(&br.mean));
            out.push(bits(&br.var));
        }
    }
    out
}

fn assert_same_trajectory(model: &str, shards: usize, expiry: Option<f32>) {
    let (mut rt_a, _, _, mut base) = fresh_trainer(model, 11);
    let (mut rt_b, _, _, mut tr) = fresh_trainer(model, 11);
    base.set_dead_code_expiry(expiry);
    tr.set_dead_code_expiry(expiry);
    tr.set_shards(shards);
    assert_eq!(tr.shards(), shards);
    for step in 0..4 {
        base.train_step(&mut rt_a).unwrap();
        tr.train_step(&mut rt_b).unwrap();
        assert_eq!(
            state_bits(&base),
            state_bits(&tr),
            "{model}: sharded trajectory (S={shards}, expiry={expiry:?}) \
             diverged at step {step}"
        );
    }
}

#[test]
fn sharded_training_is_bit_identical_per_backbone() {
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        for shards in [1usize, 2, 4] {
            assert_same_trajectory(model, shards, None);
        }
    }
}

#[test]
fn sharded_training_with_dead_code_expiry_is_bit_identical() {
    if !model_enabled("gcn") {
        return;
    }
    // a high threshold forces expiry activity every step; the re-seeding
    // RNG runs on the coordinator, so shard count still must not matter
    for shards in [2usize, 4] {
        assert_same_trajectory("gcn", shards, Some(5.0));
    }
}

fn node_requests(n: usize, count: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            if i % 7 == 5 {
                Request::Link(rng.below(n) as u32, rng.below(n) as u32)
            } else {
                Request::Node(rng.below(n) as u32)
            }
        })
        .collect()
}

fn serve_with_shards(model: &str, shards: usize) -> (Vec<Answer>, Vec<f32>) {
    let (mut rt, man, ds, mut tr) = fresh_trainer(model, 7);
    for _ in 0..3 {
        tr.train_step(&mut rt).unwrap();
    }
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let mut eng = ServeEngine::builder()
        .model(model, sm)
        .shards(shards)
        .build(rt)
        .unwrap();
    assert_eq!(eng.shards(), shards);
    assert_eq!(eng.model(model).unwrap().shards(), shards);
    assert!(eng.model(model).unwrap().threads() >= shards);
    for r in node_requests(ds.n(), 120, 0x5A4D) {
        eng.submit(model, r).unwrap();
    }
    let served: Vec<Served> = eng.drain().unwrap();
    let answers = served.iter().map(|s| s.answer.clone()).collect();
    // maintenance state fed by note_served during the drain
    let drift_bins = eng
        .model(model)
        .unwrap()
        .cache()
        .layers
        .iter()
        .flat_map(|l| l.drift_obs.bins().to_vec())
        .collect();
    (answers, drift_bins)
}

#[test]
fn sharded_serving_matches_unsharded_answers_and_maintenance() {
    for model in ["gcn", "gat"] {
        if !model_enabled(model) {
            continue;
        }
        let (base_answers, base_bins) = serve_with_shards(model, 1);
        assert!(!base_answers.is_empty());
        for shards in [2usize, 4] {
            let (answers, bins) = serve_with_shards(model, shards);
            assert_eq!(
                base_answers, answers,
                "{model}: served answers diverged at {shards} shards"
            );
            assert_eq!(
                bits(&base_bins),
                bits(&bins),
                "{model}: drift observations diverged at {shards} shards"
            );
        }
    }
}

/// Split the ROW_BLOCK chunk index range at random points, compute the
/// shared per-chunk partials per part, merge in global chunk order, and
/// compare bit-for-bit against the whole-batch kernels — the exact
/// algebra `ShardExec::update_branch` runs.
#[test]
fn random_chunk_splits_merge_to_the_unsharded_kernels() {
    let mut rng = Rng::new(0x51AB);
    for trial in 0..10 {
        let b = 1 + rng.below(4 * kernels::ROW_BLOCK + 7);
        let fp = 1 + rng.below(12);
        let k = 2 + rng.below(14);
        let v: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
        let assign: Vec<i32> = (0..b).map(|_| rng.below(k) as i32).collect();
        let n_chunks = (b + kernels::ROW_BLOCK - 1) / kernels::ROW_BLOCK;

        // random split points over the CHUNK index range (some empty)
        let parts = 1 + rng.below(5);
        let mut cuts: Vec<usize> = (0..parts - 1).map(|_| rng.below(n_chunks + 1)).collect();
        cuts.push(0);
        cuts.push(n_chunks);
        cuts.sort_unstable();

        let (m_ref, var_ref) = kernels::batch_mean_var(&v, b, fp);
        let mut mv_partials = Vec::new();
        for w in cuts.windows(2) {
            for ci in w[0]..w[1] {
                let lo = ci * kernels::ROW_BLOCK * fp;
                let hi = (lo + kernels::ROW_BLOCK * fp).min(b * fp);
                mv_partials.push(kernels::mean_var_chunk_partial(&v[lo..hi], fp));
            }
        }
        let (m, var) = kernels::mean_var_from_partials(mv_partials, b, fp);
        assert_eq!(bits(&m_ref), bits(&m), "trial {trial}: mean diverged");
        assert_eq!(bits(&var_ref), bits(&var), "trial {trial}: var diverged");

        let inv = kernels::inv_std(&var);
        let vw = kernels::whiten(&v, fp, &m, &inv);
        let (c_ref, s_ref) = kernels::cluster_accumulate(&vw, &assign, b, fp, k);
        let mut cl_partials = Vec::new();
        for w in cuts.windows(2) {
            for ci in w[0]..w[1] {
                let r0 = ci * kernels::ROW_BLOCK;
                let r1 = (r0 + kernels::ROW_BLOCK).min(b);
                cl_partials.push(kernels::cluster_chunk_partial(
                    &vw[r0 * fp..r1 * fp],
                    &assign[r0..r1],
                    fp,
                    k,
                ));
            }
        }
        let (counts, sums) = kernels::cluster_from_partials(cl_partials, fp, k);
        assert_eq!(bits(&c_ref), bits(&counts), "trial {trial}: counts diverged");
        assert_eq!(bits(&s_ref), bits(&sums), "trial {trial}: sums diverged");
    }
}

#[test]
fn shard_plan_round_trips_through_trainer_checkpoints() {
    if !model_enabled("gcn") {
        return;
    }
    let dir = std::env::temp_dir().join("vqgnn_sharded_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let (mut rt, _, _, mut tr) = fresh_trainer("gcn", 3);
    tr.set_shards(4);
    for _ in 0..2 {
        tr.train_step(&mut rt).unwrap();
    }
    let art = tr.train_art.spec.name.clone();
    let sharded = dir.join("sharded.ckpt");
    checkpoint::save_with_shards(&sharded, &art, &tr.params, &tr.vq, tr.shard_plan())
        .unwrap();
    let plain = dir.join("plain.ckpt");
    checkpoint::save(&plain, &art, &tr.params, &tr.vq).unwrap();

    let (_rt2, _, _, mut fresh) = fresh_trainer("gcn", 99);
    let plan =
        checkpoint::load_with_shards(&sharded, &art, &mut fresh.params, &mut fresh.vq)
            .unwrap();
    assert_eq!(plan.as_ref(), tr.shard_plan());
    assert_eq!(plan.as_ref().map(ShardPlan::shards), Some(4));
    // the restored state is the saved state, bit for bit
    assert_eq!(state_bits(&tr), state_bits(&fresh));
    // resuming the restored trainer under the restored plan stays on the
    // sharded==unsharded trajectory (the plan partitions the same n)
    fresh.set_shard_plan(plan);
    assert_eq!(fresh.shards(), 4);

    // an unsharded file reports no plan and restores the same bytes
    let plan = checkpoint::load_with_shards(&plain, &art, &mut fresh.params, &mut fresh.vq)
        .unwrap();
    assert!(plan.is_none());
    assert_eq!(state_bits(&tr), state_bits(&fresh));
}

#[test]
fn chunk_range_partition_is_exact() {
    for n in [0usize, 1, 5, 64, 129, 1000] {
        for s in [1usize, 2, 3, 4, 7] {
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for i in 0..s {
                let (lo, hi) = chunk_range(n, s, i);
                assert_eq!(lo, prev_end, "n={n} s={s}: ranges must be contiguous");
                assert!(hi >= lo);
                covered += hi - lo;
                prev_end = hi;
            }
            assert_eq!(prev_end, n);
            assert_eq!(covered, n);
        }
    }
}
