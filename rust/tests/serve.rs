//! Serving-subsystem correctness.
//!
//! The contract under test: the `ServeEngine` facade over a frozen
//! `ServingModel` answers queries **bit-identically** to one-shot
//! `VqTrainer::infer_nodes` on the same weights — including the padded
//! final micro-batch and duplicate node ids inside one batch — and the
//! serving-artifact export round-trips losslessly (save → load →
//! evaluate/serve identical) for all four backbones.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (CI backbone matrix).

mod common;

use std::rc::Rc;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::{checkpoint, vq_trainer::VqTrainer};
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{Answer, Request, ServeEngine, ServeError, ServingModel};
use vq_gnn::util::rng::Rng;

const BACKBONES: [&str; 4] = ["gcn", "sage", "gat", "txf"];

/// Train a few steps on tiny_sim so the frozen state is non-trivial
/// (codebooks data-driven, assignments touched by real batches).
fn trained(model: &str, steps: usize, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
            .unwrap();
    for _ in 0..steps {
        tr.train_step(&mut rt).unwrap();
    }
    (rt, man, ds, tr)
}

/// Query mix exercising the hard cases: duplicates adjacent (same
/// micro-batch), duplicates far apart (different batches), and a length
/// that is NOT a multiple of b (padded final micro-batch).
fn query_nodes(n: usize, count: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut q: Vec<u32> = (0..count).map(|_| rng.below(n) as u32).collect();
    q[1] = q[0]; // adjacent duplicate in the first batch
    let last = q.len() - 1;
    q[last] = q[0]; // far-apart duplicate, lands in the padded tail batch
    q
}

#[test]
fn serve_batched_matches_one_shot_inference() {
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, mut tr) = trained(model, 3, 7);
        let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let b = sm.batch_size();
        let c = sm.out_dim();
        // 333 = 5·64 + 13 → five full micro-batches + one padded tail
        let queries = query_nodes(ds.n(), 333, 0xC0FFEE ^ b as u64);
        assert_ne!(queries.len() % b, 0, "want a padded tail batch");

        let mut eng = ServeEngine::builder().model(model, sm).build(rt).unwrap();
        for &v in &queries {
            eng.submit(model, Request::Node(v)).unwrap();
        }
        let served = eng.drain().unwrap();
        assert_eq!(served.len(), queries.len());
        let st = eng.stats(model).unwrap();
        assert_eq!(st.batches_run as usize, (queries.len() + b - 1) / b);
        assert_eq!(st.padded_rows as usize, b - queries.len() % b);
        assert_eq!(st.last_flush_padded_rows, st.padded_rows);
        assert_eq!(st.tail_forced_flushes, 1, "drain forced the padded tail");
        assert_eq!(st.tail_deadline_flushes, 0);

        let want = tr.infer_nodes(eng.runtime_mut(), &queries).unwrap();
        for (i, s) in served.iter().enumerate() {
            assert_eq!(s.id, i, "{model}: answers come back in submit order");
            match &s.answer {
                Answer::Scores(scores) => {
                    assert_eq!(
                        scores.as_slice(),
                        &want[i * c..(i + 1) * c],
                        "{model}: row {i} (node {}) diverged from one-shot inference",
                        queries[i]
                    );
                }
                other => panic!("{model}: node query answered with {other:?}"),
            }
        }
        // duplicate occurrences answer identically
        let (a0, a1) = (&served[0].answer, &served[1].answer);
        assert_eq!(a0, a1, "{model}: adjacent duplicates disagree");
    }
}

#[test]
fn link_requests_are_dot_products_of_rows() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, mut tr) = trained("gcn", 2, 11);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let c = sm.out_dim();
    // mixed stream: link endpoints expand into the node-slot order
    let reqs = [
        Request::Node(5),
        Request::Link(9, 17),
        Request::Node(9),
        Request::Link(0, 5),
    ];
    let slots: Vec<u32> = vec![5, 9, 17, 9, 0, 5];
    let mut eng = ServeEngine::builder().model("gcn", sm).build(rt).unwrap();
    for r in reqs {
        eng.submit("gcn", r).unwrap();
    }
    let served = eng.drain().unwrap();
    let rows = tr.infer_nodes(eng.runtime_mut(), &slots).unwrap();
    let dot = |i: usize, j: usize| -> f32 {
        rows[i * c..(i + 1) * c]
            .iter()
            .zip(&rows[j * c..(j + 1) * c])
            .map(|(x, y)| x * y)
            .sum()
    };
    assert_eq!(served[0].answer, Answer::Scores(rows[0..c].to_vec()));
    assert_eq!(served[1].answer, Answer::Link(dot(1, 2)));
    assert_eq!(served[2].answer, Answer::Scores(rows[3 * c..4 * c].to_vec()));
    assert_eq!(served[3].answer, Answer::Link(dot(4, 5)));
}

#[test]
fn checkpoint_roundtrip_evaluate_bit_identical_all_backbones() {
    let dir = std::env::temp_dir().join("vqgnn_serve_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, mut tr) = trained(model, 2, 3);
        let m0 = tr.evaluate(&mut rt, Split::Test).unwrap();

        // --- training checkpoint: save → load into a fresh trainer -------
        let art = format!("vq_train_tiny_sim_{model}");
        let ckpt = dir.join(format!("{model}.ckpt"));
        checkpoint::save(&ckpt, &art, &tr.params, &tr.vq).unwrap();
        let mut tr2 = VqTrainer::new(
            &mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, 99,
        )
        .unwrap();
        checkpoint::load(&ckpt, &art, &mut tr2.params, &mut tr2.vq).unwrap();
        let m1 = tr2.evaluate(&mut rt, Split::Test).unwrap();
        assert_eq!(m0.to_bits(), m1.to_bits(), "{model}: evaluate drifted across restore");

        // --- serving artifact: freeze → save → load → serve identical ----
        let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let sckpt = dir.join(format!("{model}.serve.bin"));
        sm.save(&sckpt).unwrap();
        let sm2 = ServingModel::load(&mut rt, &man, ds.clone(), model, &sckpt).unwrap();
        assert_eq!(sm.cache().memory_bytes(), sm2.cache().memory_bytes());

        // the wrong backbone's serving artifact is refused
        if model == "gcn" {
            assert!(ServingModel::load(&mut rt, &man, ds.clone(), "sage", &sckpt).is_err());
        }

        // both artifacts behind ONE engine (multi-model routing): the
        // reloaded model must answer bit-identically next to the original
        let queries = query_nodes(ds.n(), 100, 5); // 100 = 64 + 36 → padded tail
        let mut eng = ServeEngine::builder()
            .model("orig", sm)
            .model("reloaded", sm2)
            .build(rt)
            .unwrap();
        for &v in &queries {
            eng.submit("orig", Request::Node(v)).unwrap(); // ticket 2i
            eng.submit("reloaded", Request::Node(v)).unwrap(); // ticket 2i+1
        }
        let served = eng.drain().unwrap();
        assert_eq!(served.len(), 2 * queries.len());
        let c = eng.model("orig").unwrap().out_dim();
        let want = tr.infer_nodes(eng.runtime_mut(), &queries).unwrap();
        for i in 0..queries.len() {
            let (s1, s2) = (&served[2 * i], &served[2 * i + 1]);
            assert_eq!(s1.id, 2 * i, "global ticket order interleaves the models");
            assert_eq!(s2.id, 2 * i + 1);
            assert_eq!(
                s1.answer, s2.answer,
                "{model}: reloaded serving artifact answers differently"
            );
            assert_eq!(
                s1.answer,
                Answer::Scores(want[i * c..(i + 1) * c].to_vec()),
                "{model}: frozen serve diverged from trainer inference"
            );
        }
    }
}

#[test]
fn out_of_range_node_id_is_an_error_not_a_panic() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 1, 2);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let total = sm.total_nodes();
    let mut eng = ServeEngine::builder().model("gcn", sm).build(rt).unwrap();
    // refused AT SUBMIT with a typed error — a request-controlled id must
    // fail alone, never reach a flush where it would poison the batch
    let err = eng.submit("gcn", Request::Node(ds.n() as u32)).unwrap_err();
    assert_eq!(
        err,
        ServeError::InvalidNode { model: "gcn".into(), id: ds.n() as u32, total }
    );
    assert!(!err.to_string().is_empty());
    // the queue stays usable after the refusal
    eng.submit("gcn", Request::Node(0)).unwrap();
    let served = eng.drain().unwrap();
    assert_eq!(served.len(), 1);
}

#[test]
fn empty_drain_is_a_noop() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 1);
    let sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let mut eng = ServeEngine::builder().model("gcn", sm).build(rt).unwrap();
    let served = eng.drain().unwrap();
    assert!(served.is_empty());
    assert_eq!(eng.stats("gcn").unwrap().batches_run, 0);
    assert_eq!(eng.pending(), 0);
}
