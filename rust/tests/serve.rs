//! Serving-subsystem correctness.
//!
//! The contract under test: the micro-batching engine over a frozen
//! `ServingModel` answers queries **bit-identically** to one-shot
//! `VqTrainer::infer_nodes` on the same weights — including the padded
//! final micro-batch and duplicate node ids inside one batch — and the
//! serving-artifact export round-trips losslessly (save → load →
//! evaluate/serve identical) for all four backbones.
//!
//! Model-specific tests honor the `VQGNN_MODEL` filter (CI backbone matrix).

mod common;

use std::rc::Rc;

use common::{builtin, model_enabled};
use vq_gnn::coordinator::{checkpoint, vq_trainer::VqTrainer};
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::serve::{Answer, MicroBatcher, Request, ServingModel};
use vq_gnn::util::rng::Rng;

const BACKBONES: [&str; 4] = ["gcn", "sage", "gat", "txf"];

/// Train a few steps on tiny_sim so the frozen state is non-trivial
/// (codebooks data-driven, assignments touched by real batches).
fn trained(model: &str, steps: usize, seed: u64) -> (Runtime, Manifest, Rc<Dataset>, VqTrainer) {
    let man = builtin();
    let mut rt = Runtime::native();
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, seed)
            .unwrap();
    for _ in 0..steps {
        tr.train_step(&mut rt).unwrap();
    }
    (rt, man, ds, tr)
}

/// Query mix exercising the hard cases: duplicates adjacent (same
/// micro-batch), duplicates far apart (different batches), and a length
/// that is NOT a multiple of b (padded final micro-batch).
fn query_nodes(n: usize, count: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut q: Vec<u32> = (0..count).map(|_| rng.below(n) as u32).collect();
    q[1] = q[0]; // adjacent duplicate in the first batch
    let last = q.len() - 1;
    q[last] = q[0]; // far-apart duplicate, lands in the padded tail batch
    q
}

#[test]
fn serve_batched_matches_one_shot_inference() {
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, mut tr) = trained(model, 3, 7);
        let mut sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let b = sm.batch_size();
        let c = sm.out_dim();
        // 333 = 5·64 + 13 → five full micro-batches + one padded tail
        let queries = query_nodes(ds.n(), 333, 0xC0FFEE ^ b as u64);
        assert_ne!(queries.len() % b, 0, "want a padded tail batch");

        let mut eng = MicroBatcher::new();
        for &v in &queries {
            eng.submit(Request::Node(v));
        }
        let served = eng.drain(&mut rt, &mut sm).unwrap();
        assert_eq!(served.len(), queries.len());
        assert_eq!(eng.stats.batches_run as usize, (queries.len() + b - 1) / b);
        assert_eq!(eng.stats.padded_rows as usize, b - queries.len() % b);
        assert_eq!(eng.stats.last_flush_padded_rows, eng.stats.padded_rows);
        assert_eq!(eng.stats.tail_forced_flushes, 1, "drain forced the padded tail");
        assert_eq!(eng.stats.tail_deadline_flushes, 0);

        let want = tr.infer_nodes(&mut rt, &queries).unwrap();
        for (i, s) in served.iter().enumerate() {
            assert_eq!(s.id, i, "{model}: answers come back in submit order");
            match &s.answer {
                Answer::Scores(scores) => {
                    assert_eq!(
                        scores.as_slice(),
                        &want[i * c..(i + 1) * c],
                        "{model}: row {i} (node {}) diverged from one-shot inference",
                        queries[i]
                    );
                }
                other => panic!("{model}: node query answered with {other:?}"),
            }
        }
        // duplicate occurrences answer identically
        let (a0, a1) = (&served[0].answer, &served[1].answer);
        assert_eq!(a0, a1, "{model}: adjacent duplicates disagree");
    }
}

#[test]
fn link_requests_are_dot_products_of_rows() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, mut tr) = trained("gcn", 2, 11);
    let mut sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let c = sm.out_dim();
    // mixed stream: link endpoints expand into the node-slot order
    let reqs = [
        Request::Node(5),
        Request::Link(9, 17),
        Request::Node(9),
        Request::Link(0, 5),
    ];
    let slots: Vec<u32> = vec![5, 9, 17, 9, 0, 5];
    let mut eng = MicroBatcher::new();
    for r in reqs {
        eng.submit(r);
    }
    let served = eng.drain(&mut rt, &mut sm).unwrap();
    let rows = tr.infer_nodes(&mut rt, &slots).unwrap();
    let dot = |i: usize, j: usize| -> f32 {
        rows[i * c..(i + 1) * c]
            .iter()
            .zip(&rows[j * c..(j + 1) * c])
            .map(|(x, y)| x * y)
            .sum()
    };
    assert_eq!(served[0].answer, Answer::Scores(rows[0..c].to_vec()));
    assert_eq!(served[1].answer, Answer::Link(dot(1, 2)));
    assert_eq!(served[2].answer, Answer::Scores(rows[3 * c..4 * c].to_vec()));
    assert_eq!(served[3].answer, Answer::Link(dot(4, 5)));
}

#[test]
fn checkpoint_roundtrip_evaluate_bit_identical_all_backbones() {
    let dir = std::env::temp_dir().join("vqgnn_serve_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for model in BACKBONES {
        if !model_enabled(model) {
            continue;
        }
        let (mut rt, man, ds, mut tr) = trained(model, 2, 3);
        let m0 = tr.evaluate(&mut rt, Split::Test).unwrap();

        // --- training checkpoint: save → load into a fresh trainer -------
        let art = format!("vq_train_tiny_sim_{model}");
        let ckpt = dir.join(format!("{model}.ckpt"));
        checkpoint::save(&ckpt, &art, &tr.params, &tr.vq).unwrap();
        let mut tr2 = VqTrainer::new(
            &mut rt, &man, ds.clone(), model, "", NodeStrategy::Nodes, 99,
        )
        .unwrap();
        checkpoint::load(&ckpt, &art, &mut tr2.params, &mut tr2.vq).unwrap();
        let m1 = tr2.evaluate(&mut rt, Split::Test).unwrap();
        assert_eq!(m0.to_bits(), m1.to_bits(), "{model}: evaluate drifted across restore");

        // --- serving artifact: freeze → save → load → serve identical ----
        let mut sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
        let sckpt = dir.join(format!("{model}.serve.bin"));
        sm.save(&sckpt).unwrap();
        let mut sm2 = ServingModel::load(&mut rt, &man, ds.clone(), model, &sckpt).unwrap();
        assert_eq!(sm.cache().memory_bytes(), sm2.cache().memory_bytes());

        let queries = query_nodes(ds.n(), 100, 5); // 100 = 64 + 36 → padded tail
        let mut eng1 = MicroBatcher::new();
        let mut eng2 = MicroBatcher::new();
        for &v in &queries {
            eng1.submit(Request::Node(v));
            eng2.submit(Request::Node(v));
        }
        let s1 = eng1.drain(&mut rt, &mut sm).unwrap();
        let s2 = eng2.drain(&mut rt, &mut sm2).unwrap();
        let c = sm.out_dim();
        let want = tr.infer_nodes(&mut rt, &queries).unwrap();
        for i in 0..queries.len() {
            assert_eq!(
                s1[i].answer, s2[i].answer,
                "{model}: reloaded serving artifact answers differently"
            );
            assert_eq!(
                s1[i].answer,
                Answer::Scores(want[i * c..(i + 1) * c].to_vec()),
                "{model}: frozen serve diverged from trainer inference"
            );
        }

        // the wrong backbone's serving artifact is refused
        if model == "gcn" {
            assert!(ServingModel::load(&mut rt, &man, ds.clone(), "sage", &sckpt).is_err());
        }
    }
}

#[test]
fn out_of_range_node_id_is_an_error_not_a_panic() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, ds, tr) = trained("gcn", 1, 2);
    let mut sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let mut eng = MicroBatcher::new();
    eng.submit(Request::Node(ds.n() as u32)); // first invalid id
    let err = eng.drain(&mut rt, &mut sm);
    assert!(err.is_err(), "request-controlled id must not panic the server");
}

#[test]
fn empty_drain_is_a_noop() {
    if !model_enabled("gcn") {
        return;
    }
    let (mut rt, man, _ds, tr) = trained("gcn", 1, 1);
    let mut sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let mut eng = MicroBatcher::new();
    let served = eng.drain(&mut rt, &mut sm).unwrap();
    assert!(served.is_empty());
    assert_eq!(eng.stats.batches_run, 0);
}
