//! Shared helpers for the integration-test binaries (this directory module
//! is not itself a test target): the deterministic golden-input generator
//! and the `VQGNN_MODEL` backbone filter driven by the CI test matrix.

#![allow(dead_code)]

use std::path::Path;

use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::util::rng::Rng;
use vq_gnn::util::tensor::{DType, Tensor};

/// The builtin registry, even in checkouts that have AOT artifacts.
pub fn builtin() -> Manifest {
    Manifest::load_or_builtin(Path::new("/nonexistent-artifacts"))
}

/// CI backbone matrix filter: `VQGNN_MODEL=gat` (or a comma list) restricts
/// the model-specific tests to those backbones; unset/empty runs everything.
pub fn model_enabled(model: &str) -> bool {
    match std::env::var("VQGNN_MODEL") {
        Ok(v) if !v.trim().is_empty() => {
            v.split(',').any(|m| m.trim().eq_ignore_ascii_case(model))
        }
        _ => true,
    }
}

/// Deterministic well-formed inputs for an artifact spec.  The per-name
/// generation rules are mirrored verbatim by the golden generator (the
/// committed |·|-sums are meaningless if either side drifts):
///
/// - labels uniform over classes, loss weights 1;
/// - edge endpoints uniform, 30% of edges live;
/// - whitening variances in [0.5, 1.5);
/// - fixed-conv sketches sparse (20% fill) and mildly scaled;
/// - attention masks 𝔠 = A+I-shaped (15% fill + diagonal), count sketches
///   nonnegative small integers, global histograms in [0, 24) — shaped like
///   what the sketch builders emit, so attention denominators stay away
///   from the mass floor;
/// - everything else 0.3·gaussian.
pub fn golden_inputs(man: &Manifest, name: &str, rng: &mut Rng) -> Vec<Tensor> {
    let spec = man.artifact(name).unwrap();
    // logits-less artifacts (vq_assign) have no label inputs either, so the
    // class count is never read for them
    let classes = spec
        .outputs
        .iter()
        .find(|t| t.name == "logits")
        .map_or(1, |t| t.shape[1]);
    spec.inputs
        .iter()
        .map(|ts| {
            let n = ts.numel();
            match (ts.name.as_str(), ts.dtype) {
                ("y", DType::I32) => Tensor::from_i32(
                    &ts.shape,
                    (0..n).map(|_| rng.below(classes) as i32).collect(),
                ),
                ("wloss", _) => Tensor::from_f32(&ts.shape, vec![1.0; n]),
                ("esrc", _) | ("edst", _) => Tensor::from_i32(
                    &ts.shape,
                    (0..n).map(|_| rng.below(spec.nn) as i32).collect(),
                ),
                ("ecoef", _) => Tensor::from_f32(
                    &ts.shape,
                    (0..n).map(|_| if rng.f64() < 0.3 { rng.f32() } else { 0.0 }).collect(),
                ),
                (nm, DType::F32) if nm.ends_with(".var") => {
                    Tensor::from_f32(&ts.shape, (0..n).map(|_| 0.5 + rng.f32()).collect())
                }
                (nm, DType::F32) if nm.ends_with(".c_out") || nm.ends_with(".ct_out") => {
                    Tensor::from_f32(
                        &ts.shape,
                        (0..n)
                            .map(|_| if rng.f64() < 0.2 { 0.5 * rng.f32() } else { 0.0 })
                            .collect(),
                    )
                }
                (nm, DType::F32) if nm.ends_with(".c_in") => Tensor::from_f32(
                    &ts.shape,
                    (0..n).map(|_| 0.15 * rng.gauss_f32()).collect(),
                ),
                (nm, DType::F32) if nm.ends_with(".mask_in") => {
                    let b = ts.shape[0];
                    let mut m: Vec<f32> = (0..n)
                        .map(|_| if rng.f64() < 0.15 { 1.0 } else { 0.0 })
                        .collect();
                    for i in 0..b {
                        m[i * b + i] = 1.0;
                    }
                    Tensor::from_f32(&ts.shape, m)
                }
                (nm, DType::F32) if nm.ends_with(".m_out") || nm.ends_with(".m_out_t") => {
                    Tensor::from_f32(
                        &ts.shape,
                        (0..n)
                            .map(|_| {
                                if rng.f64() < 0.3 {
                                    (1 + rng.below(3)) as f32
                                } else {
                                    0.0
                                }
                            })
                            .collect(),
                    )
                }
                (nm, DType::F32) if nm.ends_with(".cnt_out") => Tensor::from_f32(
                    &ts.shape,
                    (0..n).map(|_| rng.below(24) as f32).collect(),
                ),
                (_, DType::F32) => Tensor::from_f32(
                    &ts.shape,
                    (0..n).map(|_| 0.3 * rng.gauss_f32()).collect(),
                ),
                (_, DType::I32) => Tensor::from_i32(&ts.shape, vec![0; n]),
            }
        })
        .collect()
}
