//! End-to-end benchmarks: steps/sec per method on arxiv_sim (one per
//! paper-table row family) plus both inference paths — the measured numbers
//! behind Table 3 / Fig. 4 / the §6 inference comparison.
//!
//!   cargo bench --offline

use std::rc::Rc;

use vq_gnn::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::util::bench::bench;

fn main() {
    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let mut rt = Runtime::new().unwrap();
    let ds = Rc::new(Dataset::generate(&man.datasets["arxiv_sim"], 42));

    // --- training steps per method (Table 3 / Fig. 4 substrate) ----------
    let mut vq =
        VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "", NodeStrategy::Nodes, 1)
            .unwrap();
    vq.train_step(&mut rt).unwrap();
    bench("step/vq-gnn gcn b=512", 5.0, || {
        vq.train_step(&mut rt).unwrap();
    });

    for (name, model, kind) in [
        ("full", "gcn", Baseline::FullGraph),
        ("cluster", "gcn", Baseline::ClusterGcn),
        ("saint", "gcn", Baseline::SaintRw),
        ("ns", "sage", Baseline::NsSage),
    ] {
        let mut tr = EdgeTrainer::new(&mut rt, &man, ds.clone(), model, kind, 1).unwrap();
        tr.train_step(&mut rt).unwrap();
        bench(&format!("step/{name} {model}"), 4.0, || {
            tr.train_step(&mut rt).unwrap();
        });
    }

    // --- inference paths (§6 comparison) ----------------------------------
    let nodes: Vec<u32> = (0..ds.n() as u32).collect();
    bench("infer/vq-gnn minibatch all-nodes", 5.0, || {
        vq.infer_nodes(&mut rt, &nodes).unwrap();
    });
    let mut base =
        EdgeTrainer::new(&mut rt, &man, ds.clone(), "sage", Baseline::SaintRw, 1)
            .unwrap();
    base.train_step(&mut rt).unwrap();
    bench("infer/neighbor-expansion full-graph", 5.0, || {
        base.infer_full(&mut rt).unwrap();
    });
}
