//! Micro-benchmarks of the L3 hot paths (in-tree harness — criterion is
//! unavailable offline): blocked VQ assignment + EMA update vs the seed's
//! scalar loops, sketch building, codeword tensor assembly, a full native
//! VQ train step, and the serving read path (micro-batched inference over
//! the codebook-backed cache: `serve_qps` / `serve_p50_ms` /
//! `serve_p99_ms`).  Results are written to `BENCH_hot_paths.json` so the
//! perf trajectory accumulates across CI runs (`bench_guard` diffs them
//! against `BENCH_baseline.json`).
//!
//!   cargo bench --bench hot_paths                   # full run
//!   cargo bench --bench hot_paths -- --smoke        # CI smoke (short targets)
//!   cargo bench --bench hot_paths -- --smoke --only-serve   # serve job leg
//!
//! The headline number is the assignment speedup at k=256, fp=128, n=10k —
//! the blocked `‖v‖² − 2·v·Cᵀ + ‖c‖²` kernel vs the scalar triple loop that
//! recomputed whitening (divide + sqrt) in the innermost position.

#![allow(clippy::needless_range_loop)]

use std::collections::BTreeMap;
use std::rc::Rc;

/// With `--features alloc-count` the bench runs under a counting global
/// allocator and reports the heap bytes one steady-state train / serve step
/// requests (`train_step_alloc_bytes` / `serve_alloc_bytes`) — the
/// regression keys guarding the plan-compiled executor's reusable step
/// arena (near-zero is the contract; a hot-path `Vec` sneaking back in
/// shows up here long before it shows up as wall-clock).
#[cfg(feature = "alloc-count")]
#[global_allocator]
static GLOBAL_ALLOC: vq_gnn::util::alloc::CountingAlloc = vq_gnn::util::alloc::CountingAlloc;

/// Heap bytes requested while `f` runs (Some only under `alloc-count`).
#[cfg(feature = "alloc-count")]
fn alloc_bytes_of<F: FnOnce()>(f: F) -> Option<f64> {
    let b0 = vq_gnn::util::alloc::bytes_now();
    f();
    Some(vq_gnn::util::alloc::bytes_now().saturating_sub(b0) as f64)
}

#[cfg(not(feature = "alloc-count"))]
fn alloc_bytes_of<F: FnOnce()>(f: F) -> Option<f64> {
    f();
    None
}

use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::graph::Conv;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::util::bench::bench;
use vq_gnn::util::json::Json;
use vq_gnn::util::rng::Rng;
use vq_gnn::vq::sketch::{build_fixed, SketchScratch};
use vq_gnn::vq::{LayerVq, VqBranch, EPS};

/// The seed's scalar FINDNEAREST: per-element whitening inside the k×fp
/// inner loop.  Kept verbatim as the baseline the kernels are measured
/// against.
fn scalar_assign(br: &VqBranch, v: &[f32]) -> Vec<i32> {
    let b = v.len() / br.fp;
    let mut out = vec![0i32; b];
    for i in 0..b {
        let mut best = f32::INFINITY;
        let mut arg = 0usize;
        for c in 0..br.k {
            let mut d2 = 0.0f32;
            for d in 0..br.fp {
                let w = (v[i * br.fp + d] - br.mean[d]) / (br.var[d] + EPS).sqrt();
                let diff = w - br.cww[c * br.fp + d];
                d2 += diff * diff;
            }
            if d2 < best {
                best = d2;
                arg = c;
            }
        }
        out[i] = arg as i32;
    }
    out
}

/// The seed's scalar EMA update (per-element whitening in the scatter).
fn scalar_update(br: &mut VqBranch, v: &[f32], assign: &[i32], gamma: f32, beta: f32) {
    let b = assign.len();
    for d in 0..br.fp {
        let mut m = 0.0f64;
        for i in 0..b {
            m += v[i * br.fp + d] as f64;
        }
        let m = (m / b as f64) as f32;
        let mut va = 0.0f64;
        for i in 0..b {
            let x = v[i * br.fp + d] - m;
            va += (x * x) as f64;
        }
        let va = (va / b as f64) as f32;
        br.mean[d] = br.mean[d] * beta + m * (1.0 - beta);
        br.var[d] = br.var[d] * beta + va * (1.0 - beta);
    }
    for c in br.counts.iter_mut() {
        *c *= gamma;
    }
    for s in br.sums.iter_mut() {
        *s *= gamma;
    }
    let g1 = 1.0 - gamma;
    for i in 0..b {
        let a = assign[i] as usize;
        br.counts[a] += g1;
        for d in 0..br.fp {
            let w = (v[i * br.fp + d] - br.mean[d]) / (br.var[d] + EPS).sqrt();
            br.sums[a * br.fp + d] += g1 * w;
        }
    }
    for c in 0..br.k {
        if br.counts[c] > 1e-6 {
            for d in 0..br.fp {
                br.cww[c * br.fp + d] = br.sums[c * br.fp + d] / br.counts[c];
            }
        }
    }
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Write the report where CI expects it (workspace root, regardless of the
/// invocation cwd; override with `BENCH_OUT`).
fn write_report(report: BTreeMap<String, Json>) {
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_paths.json").to_string()
    });
    std::fs::write(&out_path, Json::Obj(report).to_string()).expect("write bench json");
    println!("wrote {out_path}");
}

/// The serving read path: train briefly, freeze, then push query bursts
/// through the [`ServeEngine`] facade — single-threaded for the
/// acceptance keys (`serve_qps`, `serve_p50_ms`, `serve_p99_ms` + a
/// detail object), the same burst across 2- and 4-worker session pools
/// (`serve_concurrent_qps_t{2,4}`), and finally an OPEN-LOOP saturation
/// driver against a bounded deadline-flushed queue: offered rates of
/// 0.5× and 4× the measured closed-loop throughput emit
/// `serve_open_loop_p99_ms_r{low,high}` (accepted-request p99) and
/// `serve_shed_rate` (fraction refused at the saturating rate).
fn bench_serve(smoke: bool, report: &mut BTreeMap<String, Json>) {
    use vq_gnn::serve::{LatencyReport, Request, ServeEngine, ServeError, ServingModel};
    use vq_gnn::util::bench::Pacer;

    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let tiny = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut rt = Runtime::native();
    let mut tr =
        VqTrainer::new(&mut rt, &man, tiny.clone(), "gcn", "", NodeStrategy::Nodes, 1).unwrap();
    for _ in 0..2 {
        tr.train_step(&mut rt).unwrap();
    }
    let mut sm = ServingModel::freeze(&mut rt, &man, &tr).unwrap();
    let b = sm.batch_size();

    // steady-state single micro-batch latency (cache hit path)
    let mut rq = Rng::new(0x5E57E);
    let batch: Vec<u32> = (0..b).map(|_| rq.below(tiny.n()) as u32).collect();
    sm.forward_batch(&mut rt, &batch).unwrap(); // warm
    let r_fb = bench("serve/forward_batch tiny gcn b=64", if smoke { 0.3 } else { 1.5 }, || {
        std::hint::black_box(sm.forward_batch(&mut rt, &batch).unwrap());
    });
    report.insert("serve_forward_batch_ms".into(), num(r_fb.mean_ns / 1e6));
    // steady-state allocation of one micro-batch through the reused
    // serving session + step arena (the ~0-bytes contract)
    if let Some(bytes) = alloc_bytes_of(|| {
        std::hint::black_box(sm.forward_batch(&mut rt, &batch).unwrap());
    }) {
        println!("serve/forward_batch alloc: {bytes} bytes/step");
        report.insert("serve_alloc_bytes".into(), num(bytes));
    }

    // marginal cost of ONE extra pool worker: the constant input template
    // (params + codebooks) is Arc-shared across sessions, so a new worker
    // allocates only its dynamic slots + arena + scratch — this key pins
    // the sharing (a per-worker template copy would blow it up by
    // template_bytes)
    if let Some(bytes) = alloc_bytes_of(|| {
        sm.set_threads(2);
    }) {
        println!(
            "serve/session alloc: {bytes} bytes/worker (template {} B shared once, \
             dynamic slots {} B per worker)",
            sm.core.template_bytes(),
            sm.worker_dyn_bytes()
        );
        report.insert("serve_session_alloc_bytes".into(), num(bytes));
    }
    sm.set_threads(1);

    // ---- closed-loop bursts through the facade --------------------------
    let n_req = if smoke { 2_000 } else { 10_000 };
    let burst_seed = rq.next_u64();
    let mut eng = ServeEngine::builder().model("gcn", sm).build(rt).unwrap();
    let wall1 = {
        let mut rb = Rng::new(burst_seed);
        let t0 = std::time::Instant::now();
        for _ in 0..n_req {
            eng.submit("gcn", Request::Node(rb.below(tiny.n()) as u32)).unwrap();
        }
        let served = eng.drain().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let lat: Vec<f64> = served.iter().map(|s| s.latency_s).collect();
        let lr = LatencyReport::from_latencies(&lat, wall);
        report_serve(
            report,
            &lr,
            eng.stats("gcn").unwrap().batches_run,
            eng.model("gcn").unwrap(),
        );
        wall
    };
    let closed_qps = n_req as f64 / wall1.max(1e-12);

    // the same burst fanned across 2- and 4-worker session pools: answers
    // are bit-identical (tests/serve_concurrent.rs); these keys track the
    // throughput scaling of the shared-plan pool
    for threads in [2usize, 4] {
        eng.set_threads(threads);
        let mut rb = Rng::new(burst_seed);
        let t0 = std::time::Instant::now();
        for _ in 0..n_req {
            eng.submit("gcn", Request::Node(rb.below(tiny.n()) as u32)).unwrap();
        }
        let served = eng.drain().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let qps = served.len() as f64 / wall.max(1e-12);
        println!(
            "serve/engine tiny gcn x{threads}: {:.0} qps ({:.2}x vs single)",
            qps,
            wall1 / wall.max(1e-12)
        );
        report.insert(format!("serve_concurrent_qps_t{threads}"), num(qps));
    }

    // single-worker drain of the same burst: every flush hands the worker
    // a multi-batch bucket, so this measures the prep(i+1)/exec(i) overlap
    // inside `run_batches_pipelined` (answers stay byte-identical to the
    // serial loop — tests/serve_concurrent.rs pins that)
    eng.set_threads(1);
    {
        let mut rb = Rng::new(burst_seed);
        let t0 = std::time::Instant::now();
        for _ in 0..n_req {
            eng.submit("gcn", Request::Node(rb.below(tiny.n()) as u32)).unwrap();
        }
        let served = eng.drain().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let qps = served.len() as f64 / wall.max(1e-12);
        println!(
            "serve/pipelined tiny gcn x1: {qps:.0} qps ({:.2}x vs first burst)",
            wall1 / wall.max(1e-12)
        );
        report.insert("serve_pipelined_qps".into(), num(qps));
    }

    // ---- sharded maintenance fan-out: same burst, answers unchanged -----
    // Rebuild behind `.shards(2)`: the session pool widens to 2 workers
    // and note_served / TTL scans fan across shard workers keyed by the
    // node partition map, with results merged in serial order
    // (tests/sharded.rs pins byte-identity).  This key tracks the
    // end-to-end throughput with the sharded maintenance path engaged.
    let (rt, models) = eng.into_parts();
    let mut builder = ServeEngine::builder().threads(1).shards(2);
    for (name, m) in models {
        builder = builder.model(name, m);
    }
    let mut eng = builder.build(rt).unwrap();
    {
        let mut rb = Rng::new(burst_seed);
        let t0 = std::time::Instant::now();
        for _ in 0..n_req {
            eng.submit("gcn", Request::Node(rb.below(tiny.n()) as u32)).unwrap();
        }
        let served = eng.drain().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let qps = served.len() as f64 / wall.max(1e-12);
        println!(
            "serve/sharded tiny gcn S=2: {qps:.0} qps ({:.2}x vs first burst)",
            wall1 / wall.max(1e-12)
        );
        report.insert("serve_sharded_qps_s2".into(), num(qps));
    }

    // ---- open-loop saturation: bounded queue + deadline flushing --------
    // Rebuild the SAME frozen model behind a load-shedding configuration
    // (no re-freeze — into_parts hands the parts back).
    let (rt, models) = eng.into_parts();
    let mut builder = ServeEngine::builder()
        .threads(1)
        .deadline(std::time::Duration::from_millis(5))
        .queue_cap(4 * b);
    for (name, m) in models {
        builder = builder.model(name, m);
    }
    let mut eng = builder.build(rt).unwrap();
    let n_open = if smoke { 1_000 } else { 5_000 };
    let mut open_loop = |rate: f64, seed: u64| -> (f64, f64) {
        let mut rb = Rng::new(seed);
        let mut pacer = Pacer::new(rate);
        let mut offered = 0usize;
        let mut shed = 0usize;
        let mut lat: Vec<f64> = Vec::new();
        let t0 = std::time::Instant::now();
        while offered < n_open {
            let due = pacer.due().min(n_open - offered);
            if due == 0 {
                pacer.sleep_until_next(std::time::Duration::from_millis(1));
            }
            for _ in 0..due {
                offered += 1;
                match eng.submit("gcn", Request::Node(rb.below(tiny.n()) as u32)) {
                    Ok(_) => {}
                    Err(ServeError::Shed { .. }) => shed += 1,
                    Err(e) => panic!("open-loop submit: {e}"),
                }
            }
            pacer.note_issued(due);
            for s in eng.poll().unwrap() {
                lat.push(s.latency_s);
            }
        }
        for s in eng.drain().unwrap() {
            lat.push(s.latency_s);
        }
        let wall = t0.elapsed().as_secs_f64();
        let p99 = if lat.is_empty() {
            0.0
        } else {
            LatencyReport::from_latencies(&lat, wall).p99_ms
        };
        (p99, shed as f64 / offered.max(1) as f64)
    };
    // 0.5× capacity: no shedding expected, p99 bounded by the deadline
    let (p99_low, shed_low) = open_loop(0.5 * closed_qps, burst_seed.wrapping_add(1));
    // 4× capacity: saturating — the bounded queue MUST shed, and accepted
    // requests' p99 stays near queue-drain + deadline, not offered-rate
    let (p99_high, shed_high) = open_loop(4.0 * closed_qps, burst_seed.wrapping_add(2));
    println!(
        "serve/open_loop tiny gcn: rlow p99 {p99_low:.3} ms (shed {:.1}%), \
         rhigh p99 {p99_high:.3} ms (shed {:.1}%)",
        100.0 * shed_low,
        100.0 * shed_high
    );
    report.insert("serve_open_loop_p99_ms_rlow".into(), num(p99_low));
    report.insert("serve_open_loop_p99_ms_rhigh".into(), num(p99_high));
    report.insert("serve_shed_rate".into(), num(shed_high));

    // ---- online maintenance: admit-at-cap and the drift probe -----------
    // Rebuild behind an LRU cap, fill to it, then time the steady state
    // where every admission pays for one inline eviction (assignment of
    // one row against every layer's codebooks + table compaction) — the
    // cost a long-running host pays per streamed node.
    let (rt, models) = eng.into_parts();
    // live registry on this engine: the admit/evict/drift benches below
    // feed real histogram families for the scrape-cost key
    let obs_reg = std::sync::Arc::new(vq_gnn::obs::Registry::new());
    let mut builder = ServeEngine::builder()
        .threads(1)
        .max_admitted(64)
        .metrics(obs_reg.clone());
    for (name, m) in models {
        builder = builder.model(name, m);
    }
    let mut eng = builder.build(rt).unwrap();
    let feat = tiny.feature_row(0).to_vec();
    let nn = tiny.n() as u32;
    for i in 0..64u32 {
        eng.admit("gcn", &feat, &[i % nn]).unwrap();
    }
    let mut nb = 0u32;
    let r_ae = bench("serve/admit_evict tiny gcn cap=64", if smoke { 0.3 } else { 1.0 }, || {
        nb = (nb + 1) % nn;
        std::hint::black_box(eng.admit("gcn", &feat, &[nb]).unwrap());
    });
    report.insert("serve_admit_evict_ms".into(), num(r_ae.mean_ns / 1e6));
    // the codebook-drift metric (per-layer histogram TV distance) — read
    // on every flush-side alert check, so it must stay branch-cheap
    let r_dr = bench("serve/drift_check tiny gcn", if smoke { 0.3 } else { 1.0 }, || {
        std::hint::black_box(eng.drift("gcn").unwrap());
    });
    report.insert("serve_drift_check_ms".into(), num(r_dr.mean_ns / 1e6));

    // ---- observability: scrape cost + raw record overhead ---------------
    // One STATS answer end-to-end: render the Prometheus exposition from
    // the live registry (fed by the benches above) and frame the reply —
    // what the server pays per scrape while serving.
    use vq_gnn::serve::proto::{encode_response, WireResponse};
    let r_sc = bench("obs/stats_scrape render+frame", if smoke { 0.2 } else { 0.5 }, || {
        let text = obs_reg.render_prometheus();
        std::hint::black_box(encode_response(&WireResponse::Stats { req_id: 0, text }));
    });
    report.insert("serve_stats_scrape_ms".into(), num(r_sc.mean_ns / 1e6));

    // one Histogram::record — the per-sample data-path tax with metrics ON
    // (a handful of relaxed atomic RMWs); reported in nanoseconds
    let h = vq_gnn::obs::Histogram::new();
    let mut x = 0u64;
    let r_rec = bench("obs/histogram_record", if smoke { 0.2 } else { 0.5 }, || {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h.record(x & 0xF_FFFF);
    });
    report.insert("obs_record_overhead_ns".into(), num(r_rec.mean_ns));

    // full registry dump rides along for post-hoc inspection
    report.insert("obs".into(), obs_reg.to_json());
}

/// Emit the single-threaded serve acceptance keys + detail object.
fn report_serve(
    report: &mut BTreeMap<String, Json>,
    lr: &vq_gnn::serve::LatencyReport,
    batches: u64,
    sm: &vq_gnn::serve::ServingModel,
) {
    println!("serve/engine tiny gcn: {lr}");
    report.insert("serve_qps".into(), num(lr.qps));
    report.insert("serve_p50_ms".into(), num(lr.p50_ms));
    report.insert("serve_p99_ms".into(), num(lr.p99_ms));
    let mut s = BTreeMap::new();
    s.insert("requests".into(), num(lr.count as f64));
    s.insert("batch_b".into(), num(sm.batch_size() as f64));
    s.insert("batches".into(), num(batches as f64));
    s.insert("mean_ms".into(), num(lr.mean_ms));
    s.insert("cache_bytes".into(), num(sm.cache().memory_bytes() as f64));
    report.insert("serve".into(), Json::Obj(s));
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let only_serve = std::env::args().any(|a| a == "--only-serve");
    let t = |full: f64, short: f64| if smoke { short } else { full };
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("bench".into(), Json::Str("hot_paths".into()));
    report.insert("mode".into(), Json::Str(if smoke { "smoke" } else { "full" }.into()));
    report.insert("threads".into(), num(vq_gnn::util::par::max_threads() as f64));
    // which kernel dispatch this run used ("avx2" / "neon" / "scalar") —
    // a string, so bench_guard ignores it; CI greps it out of the artifact
    // to catch a runner silently falling back to scalar
    report.insert("simd_dispatch".into(), Json::Str(vq_gnn::util::simd::name().into()));

    bench_serve(smoke, &mut report);
    if only_serve {
        write_report(report);
        return;
    }

    // --- VQ assignment: acceptance config k=256, fp=128, n=10k -----------
    let (k, fp, n) = (256usize, 128usize, 10_000usize);
    let mut rng = Rng::new(1);
    let mut br = VqBranch::init(k, fp, &mut rng);
    for d in 0..fp {
        br.mean[d] = 0.1 * rng.gauss_f32();
        br.var[d] = 0.5 + rng.f32();
    }
    let v: Vec<f32> = (0..n * fp).map(|_| rng.gauss_f32()).collect();
    // Parity before timing.  The two float paths can disagree on exact
    // near-ties (distances equal within f32 rounding at fp=128), which is
    // semantically a tie — bound the rate instead of demanding bit equality.
    let mismatches = scalar_assign(&br, &v)
        .iter()
        .zip(br.assign_host(&v).iter())
        .filter(|(a, b2)| a != b2)
        .count();
    assert!(
        mismatches * 1000 < n,
        "assign parity: {mismatches}/{n} rows disagree with the scalar loop"
    );
    let r_scalar = bench("vq_assign/scalar  k=256 fp=128 n=10k", t(3.0, 0.4), || {
        std::hint::black_box(scalar_assign(&br, &v));
    });
    let r_blocked = bench("vq_assign/blocked k=256 fp=128 n=10k", t(3.0, 0.4), || {
        std::hint::black_box(br.assign_host(&v));
    });
    let speedup = r_scalar.mean_ns / r_blocked.mean_ns.max(1e-9);
    println!("vq_assign speedup: {speedup:.2}x (target >= 4x)");
    if speedup < 4.0 {
        eprintln!("WARNING: assignment speedup {speedup:.2}x below the 4x target");
    }
    let secs = r_blocked.mean_ns / 1e9;
    let mut a = BTreeMap::new();
    a.insert("n".into(), num(n as f64));
    a.insert("k".into(), num(k as f64));
    a.insert("fp".into(), num(fp as f64));
    a.insert("scalar_ms".into(), num(r_scalar.mean_ns / 1e6));
    a.insert("blocked_ms".into(), num(r_blocked.mean_ns / 1e6));
    a.insert("speedup".into(), num(speedup));
    a.insert("vectors_per_sec".into(), num(n as f64 / secs));
    a.insert("codewords_per_sec".into(), num((n * k) as f64 / secs));
    report.insert("assign".into(), Json::Obj(a));

    // --- SIMD exact kernel + two-stage FINDNEAREST prune, same shapes -----
    // `assign_simd_ms` times the dispatched exact kernel alone (whitening
    // and codeword norms hoisted out, as the trainer's hot loop sees it);
    // `findnearest_prune_ms` times the i8 first pass + f32 rescore, then
    // asserts bit-exact agreement with the exact kernel — the prune's
    // correctness contract, not a tolerance.
    {
        use vq_gnn::vq::kernels;
        let inv = kernels::inv_std(&br.var);
        let vw = kernels::whiten(&v, fp, &br.mean, &inv);
        let mut cnorm = vec![0.0f32; k];
        kernels::codeword_norms_into(&br.cww, k, fp, fp, &mut cnorm);
        let mut out_b = vec![0i32; n];
        let r_simd = bench("vq_assign/simd    k=256 fp=128 n=10k", t(3.0, 0.4), || {
            kernels::assign_blocked_with_norms(&vw, fp, fp, &br.cww, k, fp, &cnorm, &mut out_b);
            std::hint::black_box(&out_b);
        });
        report.insert("assign_simd_ms".into(), num(r_simd.mean_ns / 1e6));

        let qcb = kernels::QuantCodebook::build(&br.cww, k, fp, fp);
        let mut out_p = vec![0i32; n];
        let r_prune = bench("vq_assign/pruned  k=256 fp=128 n=10k m=16", t(3.0, 0.4), || {
            kernels::assign_pruned(
                &vw, fp, fp, &br.cww, fp, &qcb, kernels::PRUNE_TOP_M, &mut out_p,
            );
            std::hint::black_box(&out_p);
        });
        report.insert("findnearest_prune_ms".into(), num(r_prune.mean_ns / 1e6));
        assert_eq!(out_p, out_b, "pruned assignment diverged from the exact kernel");
    }

    // --- VQ EMA update, same shapes ---------------------------------------
    let assign = br.assign_host(&v);
    let mut br_s = br.clone();
    let r_su = bench("vq_update/scalar  k=256 fp=128 b=10k", t(2.0, 0.3), || {
        scalar_update(&mut br_s, &v, &assign, 0.99, 0.99);
    });
    let mut br_k = br.clone();
    let r_ku = bench("vq_update/blocked k=256 fp=128 b=10k", t(2.0, 0.3), || {
        br_k.update(&v, &assign, 0.99, 0.99);
    });
    let upd_speedup = r_su.mean_ns / r_ku.mean_ns.max(1e-9);
    println!("vq_update speedup: {upd_speedup:.2}x");
    let usecs = r_ku.mean_ns / 1e9;
    let mut u = BTreeMap::new();
    u.insert("b".into(), num(n as f64));
    u.insert("k".into(), num(k as f64));
    u.insert("fp".into(), num(fp as f64));
    u.insert("scalar_ms".into(), num(r_su.mean_ns / 1e6));
    u.insert("blocked_ms".into(), num(r_ku.mean_ns / 1e6));
    u.insert("speedup".into(), num(upd_speedup));
    u.insert("vectors_per_sec".into(), num(n as f64 / usecs));
    // distinct name from assign's `codewords_per_sec` (n·k distance evals/s):
    // an update refreshes the k-codeword book once per call
    u.insert("codewords_refreshed_per_sec".into(), num(k as f64 / usecs));
    report.insert("update".into(), Json::Obj(u));

    // --- sharded EMA broadcast→merge cycle, same shapes -------------------
    // One full `ShardExec::update_branch` round trip at S=2: broadcast the
    // whitening stats, shards compute chunk partials over their resident
    // ranges, coordinator merges in global chunk order (bit-identical to
    // `update` above — tests/sharded.rs pins it).  The delta vs
    // `update.blocked_ms` is the fan-out + merge tax per branch per step.
    {
        use std::sync::Arc;
        use vq_gnn::shard::{ShardExec, ShardPlan};
        let exec = ShardExec::new(ShardPlan::contiguous(n, 2));
        let va = Arc::new(v.clone());
        let aa = Arc::new(assign.clone());
        let mut br_m = br.clone();
        let r_sm = bench("shard_merge/update_branch k=256 fp=128 b=10k S=2", t(2.0, 0.3), || {
            exec.update_branch(&mut br_m, &va, &aa, 0.99, 0.99, None);
        });
        report.insert("shard_merge_ms".into(), num(r_sm.mean_ns / 1e6));
    }

    // --- sketch building (the per-step O(b·d·B) scan) ---------------------
    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let ds = Rc::new(Dataset::generate(&man.datasets["arxiv_sim"], 42));
    let spec = man.artifact("vq_train_arxiv_sim_gcn").unwrap();
    let layer = LayerVq::init(&spec.plan[1], spec.k, ds.n(), &mut rng);
    let batch: Vec<u32> = rng.sample_distinct(ds.n(), spec.b);
    let mut scratch = SketchScratch::new(ds.n());
    let r_sk = bench("sketch_build/gcn b=512 k=128 B=8", t(1.5, 0.3), || {
        let (a, b2, c) = build_fixed(&ds.graph, Conv::GcnSym, &batch, &layer, &mut scratch);
        std::hint::black_box((a, b2, c));
    });
    report.insert("sketch_build_ms".into(), num(r_sk.mean_ns / 1e6));

    // --- codeword tensor assembly ------------------------------------------
    let r_cw = bench("codeword_tensors/layer", t(1.0, 0.2), || {
        std::hint::black_box((layer.cw_tensor(), layer.cww_tensor()));
    });
    report.insert("codeword_tensors_ms".into(), num(r_cw.mean_ns / 1e6));

    // --- full native VQ train step (sketches + execute + updates) ---------
    let tiny = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    let mut rt = Runtime::native();
    let mut tr =
        VqTrainer::new(&mut rt, &man, tiny.clone(), "gcn", "", NodeStrategy::Nodes, 1).unwrap();
    tr.train_step(&mut rt).unwrap(); // warm
    let r_ts = bench("train_step/vq tiny gcn (native end-to-end)", t(2.0, 0.4), || {
        tr.train_step(&mut rt).unwrap();
    });
    report.insert("train_step_tiny_ms".into(), num(r_ts.mean_ns / 1e6));

    // steady-state allocation of one train step through the reused
    // session + step arena.  Pipelining is disabled so the number measures
    // the assembly/compute path itself, not the prefetch worker's batch
    // buffers (which live off the critical path).
    {
        let mut tr_a =
            VqTrainer::new(&mut rt, &man, tiny.clone(), "gcn", "", NodeStrategy::Nodes, 1)
                .unwrap();
        tr_a.set_pipelined(false);
        tr_a.train_step(&mut rt).unwrap(); // warm arena + sessions
        tr_a.train_step(&mut rt).unwrap();
        if let Some(bytes) = alloc_bytes_of(|| {
            tr_a.train_step(&mut rt).unwrap();
        }) {
            println!("train_step/vq tiny gcn alloc: {bytes} bytes/step");
            report.insert("train_step_alloc_bytes".into(), num(bytes));
        }
    }

    // --- sharded trainer: the same trajectory with the EMA cycle fanned ---
    // `set_shards(S)` routes every branch update through the persistent
    // shard-worker pool (broadcast→partial→merge); the trajectory is
    // bit-identical to `train_step_tiny_ms` above, so these keys measure
    // pure coordination overhead at tiny scale (the win arrives with
    // bigger b·fp; tiny pins that the tax stays bounded).
    for s in [2usize, 4] {
        let mut tr_s =
            VqTrainer::new(&mut rt, &man, tiny.clone(), "gcn", "", NodeStrategy::Nodes, 1)
                .unwrap();
        tr_s.set_shards(s);
        tr_s.train_step(&mut rt).unwrap(); // warm
        let r = bench(&format!("train_step/vq tiny gcn sharded S={s}"), t(2.0, 0.4), || {
            tr_s.train_step(&mut rt).unwrap();
        });
        report.insert(format!("train_step_sharded_ms_s{s}"), num(r.mean_ns / 1e6));
    }

    // --- attention paths: dense score tile + the learnable-conv backbones --
    {
        let b = 512usize;
        let e_src: Vec<f32> = (0..b).map(|_| rng.gauss_f32()).collect();
        let e_dst: Vec<f32> = (0..b).map(|_| rng.gauss_f32()).collect();
        let mask: Vec<f32> =
            (0..b * b).map(|_| if rng.f64() < 0.05 { 1.0 } else { 0.0 }).collect();
        let r_sc = bench("attn/gat_score_tile b=512", t(1.5, 0.3), || {
            std::hint::black_box(vq_gnn::runtime::ops::gat_score_tile(&e_dst, &e_src, &mask));
        });
        report.insert("attn_score_tile_ms".into(), num(r_sc.mean_ns / 1e6));

        for model in ["gat", "txf"] {
            let mut tra = VqTrainer::new(
                &mut rt, &man, tiny.clone(), model, "", NodeStrategy::Nodes, 1,
            )
            .unwrap();
            tra.train_step(&mut rt).unwrap(); // warm
            let r = bench(
                &format!("train_step/vq tiny {model} (native end-to-end)"),
                t(2.0, 0.4),
                || {
                    tra.train_step(&mut rt).unwrap();
                },
            );
            report.insert(format!("train_step_tiny_{model}_ms"), num(r.mean_ns / 1e6));
        }
    }

    if !smoke {
        let mut tra =
            VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "", NodeStrategy::Nodes, 1)
                .unwrap();
        tra.train_step(&mut rt).unwrap();
        let r = bench("train_step/vq arxiv gcn (native end-to-end)", 4.0, || {
            tra.train_step(&mut rt).unwrap();
        });
        report.insert("train_step_arxiv_ms".into(), num(r.mean_ns / 1e6));
    }

    write_report(report);
}
