//! Micro-benchmarks of the L3 hot paths (in-tree harness — criterion is
//! unavailable offline): sketch building, VQ EMA update, batch gather,
//! codeword tensor assembly, and one full VQ train step.
//!
//!   cargo bench --offline

use std::rc::Rc;

use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::Dataset;
use vq_gnn::graph::Conv;
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;
use vq_gnn::util::bench::bench;
use vq_gnn::util::rng::Rng;
use vq_gnn::vq::sketch::{build_fixed, SketchScratch};
use vq_gnn::vq::{LayerVq, VqBranch};

fn main() {
    let man = Manifest::load(&Manifest::default_dir()).expect("run make artifacts");
    let ds = Rc::new(Dataset::generate(&man.datasets["arxiv_sim"], 42));
    let mut rng = Rng::new(1);

    // --- sketch building (the per-step O(b·d·B) scan) --------------------
    let spec = man.artifact("vq_train_arxiv_sim_gcn").unwrap();
    let layer = LayerVq::init(&spec.plan[1], spec.k, ds.n(), &mut rng);
    let batch: Vec<u32> = rng.sample_distinct(ds.n(), spec.b);
    let mut scratch = SketchScratch::new(ds.n());
    bench("sketch_build/gcn b=512 k=128 B=8", 1.5, || {
        let (a, b2, c) = build_fixed(&ds.graph, Conv::GcnSym, &batch, &layer, &mut scratch);
        std::hint::black_box((a, b2, c));
    });

    // --- VQ EMA update per branch ----------------------------------------
    let mut br = VqBranch::init(128, 16, &mut rng);
    let v: Vec<f32> = (0..512 * 16).map(|_| rng.gauss_f32()).collect();
    let assign: Vec<i32> = (0..512).map(|_| rng.below(128) as i32).collect();
    bench("vq_update/branch b=512 k=128 fp=16", 1.0, || {
        br.update(&v, &assign, 0.99, 0.99);
    });

    // --- host-side assignment (inductive bootstrap path) -----------------
    bench("vq_assign_host/branch b=512 k=128 fp=16", 1.0, || {
        std::hint::black_box(br.assign_host(&v));
    });

    // --- codeword tensor assembly -----------------------------------------
    bench("codeword_tensors/layer", 1.0, || {
        std::hint::black_box((layer.cw_tensor(), layer.cww_tensor()));
    });

    // --- feature gather -----------------------------------------------------
    bench("gather_features/b=512 f=64", 1.0, || {
        std::hint::black_box(vq_gnn::coordinator::gather_features(
            &ds.features,
            ds.cfg.f_in_pad,
            &batch,
        ));
    });

    // --- one full VQ train step (sketches + execute + updates) ------------
    let mut rt = Runtime::new().unwrap();
    let mut tr =
        VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "", NodeStrategy::Nodes, 1)
            .unwrap();
    tr.train_step(&mut rt).unwrap(); // compile + warm
    bench("train_step/vq arxiv gcn (end-to-end)", 4.0, || {
        tr.train_step(&mut rt).unwrap();
    });
}
