//! Minimal, API-compatible implementation of the subset of `anyhow` used by
//! this workspace: `Error`, `Result`, the `Context` extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Written for offline builds (no
//! registry access); source-compatible with the real crate at every call
//! site in this repo, so swapping the dependency back is a one-line change.

use std::fmt;

/// Error with a chain of context frames, outermost first.
///
/// Like the real `anyhow::Error`, this type deliberately does NOT implement
/// `std::error::Error` — that is what allows the blanket
/// `From<E: std::error::Error>` below to coexist with the std identity
/// `From<Error> for Error` used by `?` between `anyhow::Result` functions.
pub struct Error {
    /// frames[0] is the outermost context, frames[last] the root cause.
    frames: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Push a new outermost context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// Context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, outermost to root cause.
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.frames.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, frame) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {frame}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold the source chain into context frames so nothing is lost.
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("loading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("no value")?;
            ensure!(v < 10, "too big: {v}");
            if v == 3 {
                bail!("three is right out");
            }
            Ok(v)
        }
        assert_eq!(f(Some(2)).unwrap(), 2);
        assert_eq!(format!("{:#}", f(None).unwrap_err()), "no value");
        assert_eq!(format!("{}", f(Some(12)).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(Some(3)).unwrap_err()), "three is right out");
    }

    #[test]
    fn question_mark_between_anyhow_results() {
        fn inner() -> Result<()> {
            Err(Error::msg("root"))
        }
        fn outer() -> Result<()> {
            inner().with_context(|| format!("step {}", 1))?;
            Ok(())
        }
        assert_eq!(format!("{:#}", outer().unwrap_err()), "step 1: root");
    }
}
