//! Stub of the `xla-rs` PJRT bindings.
//!
//! The real bindings link against a local `xla_extension` build and are not
//! available in hermetic environments, so this crate pins the exact API
//! surface that `vq_gnn::runtime::pjrt` consumes and makes every entry point
//! fail with a clear runtime error.  Swap in a real xla-rs checkout with a
//! `[patch."..."]` (or by replacing the path dependency) to execute
//! AOT-compiled HLO artifacts for real; nothing in `vq_gnn` needs to change.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable (in-tree stub; build against a real xla-rs to enable PJRT)"
    )))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}
