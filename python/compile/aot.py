"""AOT pipeline: lower every artifact to HLO *text* + emit manifest.json.

HLO text (not serialized HloModuleProto) is the interchange format — the
image's xla_extension 0.5.1 rejects jax ≥0.5 protos with 64-bit instruction
ids; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--filter SUBSTR]
        [--jobs N] [--force]

Incremental: an artifact is skipped when its .hlo.txt already exists (the
Makefile invalidates on python source changes); the manifest is always
rewritten from the full registry.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import multiprocessing as mp
import os
import sys
import time

from . import config as C


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------


def _sub_edges(ds: C.DatasetCfg, nodes: int) -> int:
    """Padded edge capacity for a subgraph artifact: generous headroom over
    nodes·(deg+self-loop), rounded up to a power of two."""
    want = int(nodes * (ds.avg_degree + 2) * 1.6)
    cap = 1 << max(10, (want - 1).bit_length())
    return min(cap, ds.m_max)


def artifact_registry() -> list[dict]:
    """Every artifact the repo builds, with its static shape config."""
    arts: list[dict] = []
    tc = C.TRAIN

    def add(kind, ds_name, model_name, *, b=None, k=None, nn=None, ne=None,
            layers=None, suffix=""):
        name = f"{kind}_{ds_name}_{model_name}{suffix}"
        arts.append(dict(
            name=name, file=name + ".hlo.txt", kind=kind, dataset=ds_name,
            model=model_name, b=b, k=k, nn=nn, ne=ne, layers=layers,
        ))

    for ds_name, ds in C.DATASETS.items():
        tiny = ds_name == "tiny_sim"
        b = 64 if tiny else tc.b
        k = 16 if tiny else tc.k
        # txf: the Table-8 backbone (arxiv) + the tiny config the rust
        # test/gradcheck suites train hermetically (mirrors runtime/builtin.rs).
        models = ["gcn", "sage", "gat"] + (["txf"] if ds_name == "arxiv_sim" or tiny else [])
        for m in models:
            add("vq_train", ds_name, m, b=b, k=k)
            add("vq_infer", ds_name, m, b=b, k=k)
            # Forward-only serving artifact (mirrors runtime/builtin.rs).
            add("vq_serve", ds_name, m, b=b, k=k)
            if m == "txf":
                # Global attention has no edge-list form (dense n×n); the
                # paper's Table 8 evaluates txf under VQ-GNN only.
                continue
            # Full-graph exact train/infer ("oracle" rows + sampler inference).
            add("edge_train", ds_name, m, nn=ds.n, ne=ds.m_max, suffix="_full")
            add("edge_infer", ds_name, m, nn=ds.n, ne=ds.m_max, suffix="_full")
            if not tiny:
                # Cluster-GCN / GraphSAINT subgraph class.
                nn_sub = 1024
                add("edge_train", ds_name, m, nn=nn_sub,
                    ne=_sub_edges(ds, nn_sub), suffix="_sub")
        if not tiny:
            # NS-SAGE union subgraphs (not compatible with GCN — Table 4 fn.1).
            for m in ("sage", "gat"):
                nn_ns = min(ds.n, 4096)
                add("edge_train", ds_name, m, nn=nn_ns,
                    ne=min(ds.m_max, 131072), suffix="_ns")

    # Ablations (paper App. G) on arxiv_sim + GCN.
    for nl in C.ABLATION_LAYERS:
        if nl == C.MODELS["gcn"].layers:
            continue
        add("vq_train", "arxiv_sim", "gcn", b=tc.b, k=tc.k, layers=nl,
            suffix=f"_l{nl}")
        add("vq_infer", "arxiv_sim", "gcn", b=tc.b, k=tc.k, layers=nl,
            suffix=f"_l{nl}")
    for kk in C.ABLATION_CODEBOOK:
        if kk == tc.k:
            continue
        add("vq_train", "arxiv_sim", "gcn", b=tc.b, k=kk, suffix=f"_k{kk}")
        add("vq_infer", "arxiv_sim", "gcn", b=tc.b, k=kk, suffix=f"_k{kk}")
    for bb in C.ABLATION_BATCH:
        if bb == tc.b:
            continue
        add("vq_train", "arxiv_sim", "gcn", b=bb, k=tc.k, suffix=f"_b{bb}")
        add("vq_infer", "arxiv_sim", "gcn", b=bb, k=tc.k, suffix=f"_b{bb}")

    # Perf-pass variants (EXPERIMENTS.md §Perf): coarser product-VQ branches
    # (fp=32 → half the sketch volume) and the combination with k=64.
    add("vq_train", "arxiv_sim", "gcn", b=tc.b, k=tc.k, suffix="_fp32")
    add("vq_infer", "arxiv_sim", "gcn", b=tc.b, k=tc.k, suffix="_fp32")
    arts[-1]["fp"] = 32
    arts[-2]["fp"] = 32
    add("vq_train", "arxiv_sim", "gcn", b=tc.b, k=64, suffix="_fp32k64")
    add("vq_infer", "arxiv_sim", "gcn", b=tc.b, k=64, suffix="_fp32k64")
    arts[-1]["fp"] = 32
    arts[-2]["fp"] = 32

    # Standalone assignment kernel (inductive inference), per vq model family.
    for ds_name in ("ppi_sim", "tiny_sim"):
        ds = C.DATASETS[ds_name]
        b = 64 if ds_name == "tiny_sim" else tc.b
        k = 16 if ds_name == "tiny_sim" else tc.k
        model = C.MODELS["gcn"]
        from .model import make_plan
        p0 = make_plan(ds, model)[0]
        arts.append(dict(
            name=f"vq_assign_{ds_name}", file=f"vq_assign_{ds_name}.hlo.txt",
            kind="vq_assign", dataset=ds_name, model="gcn", b=b, k=k,
            nn=None, ne=None, layers=None,
            n_br=p0.n_br, fp=p0.fp,
        ))
    return arts


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def build_fn(art: dict):
    """Resolve an artifact spec to (fn, in_specs, out_specs)."""
    from . import edgemp, model
    ds = C.DATASETS[art["dataset"]]
    mo = C.MODELS[art["model"]]
    if art.get("layers"):
        mo = dataclasses.replace(mo, layers=art["layers"])
    if art.get("fp"):
        mo = dataclasses.replace(mo, fp=art["fp"])
    kind = art["kind"]
    if kind == "vq_train":
        return model.build_vq_train(ds, mo, C.TRAIN, art["b"], art["k"]), mo
    if kind == "vq_infer":
        return model.build_vq_infer(ds, mo, C.TRAIN, art["b"], art["k"]), mo
    if kind == "vq_serve":
        return model.build_vq_serve(ds, mo, C.TRAIN, art["b"], art["k"]), mo
    if kind == "edge_train":
        return edgemp.build_edge_train(ds, mo, C.TRAIN, art["nn"], art["ne"]), mo
    if kind == "edge_infer":
        return edgemp.build_edge_infer(ds, mo, C.TRAIN, art["nn"], art["ne"]), mo
    if kind == "vq_assign":
        return model.build_vq_assign_only(
            art["n_br"], art["b"], art["k"], art["fp"]), mo
    raise ValueError(kind)


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(args) -> dict:
    """Worker: lower one artifact, write HLO text, return manifest entry.
    When `skip_build` is set only the (cheap) manifest entry is produced —
    the manifest always covers the full registry even under --filter."""
    art, out_dir, force, skip_build = args
    import jax
    import jax.numpy as jnp
    t0 = time.time()
    (fn, in_specs, out_specs), mo = build_fn(art)
    entry = dict(art)
    entry["inputs"] = [dict(name=n, shape=list(s), dtype=d) for n, s, d in in_specs]
    entry["outputs"] = [dict(name=n, shape=list(s), dtype=d) for n, s, d in out_specs]
    if art["kind"].startswith("vq") and art["kind"] != "vq_assign":
        from .model import make_plan
        ds = C.DATASETS[art["dataset"]]
        entry["plan"] = [dataclasses.asdict(p) for p in make_plan(ds, mo)]
        entry["model_cfg"] = dataclasses.asdict(mo)
    path = os.path.join(out_dir, art["file"])
    if skip_build:
        entry["_built"] = False
        entry["_secs"] = round(time.time() - t0, 2)
        return entry
    if force or not os.path.exists(path):
        sp = [
            jax.ShapeDtypeStruct(s, jnp.int32 if d == "i32" else jnp.float32)
            for _, s, d in in_specs
        ]
        lowered = jax.jit(fn, keep_unused=True).lower(*sp)
        text = to_hlo_text(lowered)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        entry["_built"] = True
    else:
        entry["_built"] = False
    entry["_secs"] = round(time.time() - t0, 2)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--filter", default="")
    ap.add_argument("--jobs", type=int, default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    arts = artifact_registry()
    t0 = time.time()
    work = [(a, out_dir, args.force, args.filter not in a["name"]) for a in arts]
    if args.jobs > 1:
        with mp.get_context("spawn").Pool(args.jobs) as pool:
            entries = pool.map(lower_one, work)
    else:
        entries = [lower_one(w) for w in work]
    built = sum(e.pop("_built") for e in entries)
    for e in entries:
        e.pop("_secs", None)

    manifest = dict(
        version=1,
        train=dataclasses.asdict(C.TRAIN),
        datasets={n: dataclasses.asdict(d) for n, d in C.DATASETS.items()},
        models={n: dataclasses.asdict(m) for n, m in C.MODELS.items()},
        subgraph_shapes=C.SUBGRAPH_SHAPES,
        artifacts=entries,
    )
    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"aot: {built} built, {len(entries) - built} cached, "
          f"{len(entries)} total in {time.time() - t0:.1f}s -> {man_path}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
