"""L2: assemble VQ-GNN train / inference steps as pure flat-tuple functions.

Each artifact is a single jitted function over an explicit, ordered tuple of
arrays (the manifest records names/shapes/dtypes in the same order), so the
rust coordinator can marshal literals positionally.  The train step fuses:

  forward (Eq. 6)  →  loss head  →  backward (Eq. 7, custom VJP)  →
  per-layer probe gradients G_B^{l+1}  →  whitened VQ assignment (Alg. 2
  FINDNEAREST, L1 kernel)  →  parameter gradients

into one HLO module; the coordinator owns all cross-batch state (codebook
EMA, whitening stats, the global assignment table R) and the optimizer.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers as L
from .config import DatasetCfg, ModelCfg, TrainCfg, branch_layout, out_dim
from .kernels.vq_assign import vq_assign

EPS = 1e-5


# ---------------------------------------------------------------------------
# Layer shape plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Static shape info for one GNN layer under VQ approximation."""

    f_in: int      # input feature dim
    h_out: int     # output (pre-activation) dim
    g_dim: int     # gradient-codeword dim (h_out; 2*h_out for txf)
    n_br: int      # product-VQ branches
    fp: int        # dims per branch
    F: int         # padded concat dim == n_br * fp
    heads: int     # attention heads (1 for fixed convs / last layer)


def make_plan(ds: DatasetCfg, model: ModelCfg) -> list[LayerPlan]:
    plans = []
    f = ds.f_in_pad
    for l in range(model.layers):
        last = l == model.layers - 1
        h = out_dim(ds, model) if last else model.hidden
        heads = 1 if (last or not model.learnable_conv) else model.heads
        if model.name == "gat" and not last:
            heads = model.heads
        g_dim = 2 * h if model.name == "txf" else h
        n_br, F = branch_layout(f, g_dim, model.fp)
        fp = F // n_br
        plans.append(LayerPlan(f, h, g_dim, n_br, fp, F, heads))
        f = h
    return plans


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_specs(ds: DatasetCfg, model: ModelCfg) -> list[tuple[str, tuple]]:
    """Ordered (name, shape) list — the artifact takes params in this order
    and returns gradients in the same order."""
    specs: list[tuple[str, tuple]] = []
    for l, p in enumerate(make_plan(ds, model)):
        pre = f"l{l}."
        if model.name == "gcn":
            specs += [(pre + "w", (p.f_in, p.h_out)), (pre + "bias", (p.h_out,))]
        elif model.name == "sage":
            specs += [
                (pre + "w_self", (p.f_in, p.h_out)),
                (pre + "w_nbr", (p.f_in, p.h_out)),
                (pre + "bias", (p.h_out,)),
            ]
        elif model.name == "gat":
            hh = p.h_out // p.heads
            specs += [
                (pre + "w", (p.heads, p.f_in, hh)),
                (pre + "a_src", (p.heads, hh)),
                (pre + "a_dst", (p.heads, hh)),
                (pre + "bias", (p.h_out,)),
            ]
        elif model.name == "txf":
            hh = p.h_out // p.heads
            dk = 32
            specs += [
                (pre + "w", (p.heads, p.f_in, hh)),
                (pre + "a_src", (p.heads, hh)),
                (pre + "a_dst", (p.heads, hh)),
                (pre + "bias", (p.h_out,)),
                (pre + "wq", (p.f_in, dk)),
                (pre + "wk", (p.f_in, dk)),
                (pre + "wv", (p.f_in, p.h_out)),
                (pre + "w_lin", (p.f_in, p.h_out)),
            ]
        else:
            raise ValueError(model.name)
    return specs


def unflatten_params(model: ModelCfg, n_layers: int, flat: list) -> list[dict]:
    """Group the flat ordered param list back into per-layer dicts."""
    per_layer = {
        "gcn": ["w", "bias"],
        "sage": ["w_self", "w_nbr", "bias"],
        "gat": ["w", "a_src", "a_dst", "bias"],
        "txf": ["w", "a_src", "a_dst", "bias", "wq", "wk", "wv", "w_lin"],
    }[model.name]
    out = []
    i = 0
    for _ in range(n_layers):
        d = {}
        for key in per_layer:
            d[key] = flat[i]
            i += 1
        out.append(d)
    assert i == len(flat)
    return out


# ---------------------------------------------------------------------------
# VQ context input specs (per layer)
# ---------------------------------------------------------------------------


def ctx_specs(ds, model, plans, b: int, k: int, train: bool):
    """Ordered (name, shape, dtype) list of per-layer VQ context inputs."""
    specs = []
    for l, p in enumerate(plans):
        pre = f"l{l}."
        if model.learnable_conv:
            specs += [
                (pre + "mask_in", (b, b), "f32"),
                (pre + "m_out", (b, k), "f32"),
                (pre + "m_out_t", (b, k), "f32"),
            ]
            if model.name == "txf":
                specs += [(pre + "cnt_out", (k,), "f32")]
        else:
            specs += [
                (pre + "c_in", (b, b), "f32"),
                (pre + "c_out", (p.n_br, b, k), "f32"),
                (pre + "ct_out", (p.n_br, b, k), "f32"),
            ]
        specs += [(pre + "cw", (p.n_br, k, p.fp), "f32")]
        if train:
            specs += [
                (pre + "mean", (p.n_br, p.fp), "f32"),
                (pre + "var", (p.n_br, p.fp), "f32"),
                (pre + "cww", (p.n_br, k, p.fp), "f32"),
            ]
    return specs


def _layer_ctx(model, plan, vals, i):
    """Pop this layer's ctx entries from the flat input list."""
    ctx = {}
    if model.learnable_conv:
        ctx["mask_in"] = vals[i]; i += 1
        ctx["m_out"] = vals[i]; i += 1
        ctx["m_out_t"] = vals[i]; i += 1
        if model.name == "txf":
            ctx["cnt_out"] = vals[i]; i += 1
    else:
        ctx["c_in"] = vals[i]; i += 1
        ctx["c_out"] = vals[i]; i += 1
        ctx["ct_out"] = vals[i]; i += 1
    ctx["cw"] = vals[i]; i += 1
    ctx["gcol"] = (plan.f_in, plan.g_dim)
    return ctx, i


# ---------------------------------------------------------------------------
# Loss heads
# ---------------------------------------------------------------------------


def ce_loss(logits, y, w):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    return (w * ce).sum() / jnp.maximum(w.sum(), 1.0)


def bce_multilabel_loss(logits, y, w):
    z = logits
    per = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    per = per.mean(axis=1)
    return (w * per).sum() / jnp.maximum(w.sum(), 1.0)


def link_loss(emb, psrc, pdst, py, pw):
    logit = (emb[psrc] * emb[pdst]).sum(axis=1)
    per = jnp.maximum(logit, 0) - logit * py + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    loss = (pw * per).sum() / jnp.maximum(pw.sum(), 1.0)
    return loss, logit


# ---------------------------------------------------------------------------
# Forward pass (shared by train & infer)
# ---------------------------------------------------------------------------


def _forward(model, plans, layer_params, ctxs, xb, probes):
    """Run L layers of approximated message passing; ReLU between layers,
    linear last layer.  Returns (final output, per-layer inputs X_B^l)."""
    feats = []
    h = xb
    for l, (p, ctx) in enumerate(zip(plans, ctxs)):
        feats.append(h)
        pr = probes[l]
        if model.name == "gcn":
            y = L.gcn_layer(layer_params[l], ctx, h, pr)
        elif model.name == "sage":
            y = L.sage_layer(layer_params[l], ctx, h, pr)
        elif model.name == "gat":
            y = L.gat_layer(layer_params[l], ctx, h, pr, p.heads)
        elif model.name == "txf":
            y = L.txf_layer(layer_params[l], ctx, h, pr, p.heads)
        else:
            raise ValueError(model.name)
        h = y if l == len(plans) - 1 else jax.nn.relu(y)
    return h, feats


def _whiten_assign(plan, xfeat, gvec, mean, var, cww):
    """Whiten the concat (X_B^l ‖ G_B^{l+1}) vectors per branch and find the
    nearest codeword (Alg. 2 FINDNEAREST via the L1 kernel)."""
    b = xfeat.shape[0]
    z = jnp.zeros((b, plan.F), jnp.float32)
    z = jax.lax.dynamic_update_slice(z, xfeat, (0, 0))
    z = jax.lax.dynamic_update_slice(z, gvec, (0, plan.f_in))
    zb = z.reshape(b, plan.n_br, plan.fp).transpose(1, 0, 2)
    zw = (zb - mean[:, None, :]) / jnp.sqrt(var[:, None, :] + EPS)
    mask = jnp.ones((plan.n_br, plan.fp), jnp.float32)
    return vq_assign(zw, cww, mask), z


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


def build_vq_train(ds: DatasetCfg, model: ModelCfg, tc: TrainCfg,
                   b: int, k: int):
    """Returns (fn, input_specs, output_specs) for the VQ-GNN train step."""
    plans = make_plan(ds, model)
    pspecs = param_specs(ds, model)
    c = out_dim(ds, model)
    link = ds.task == "link"

    in_specs = [("xb", (b, ds.f_in_pad), "f32")]
    if link:
        in_specs += [
            ("psrc", (tc.p_pairs,), "i32"),
            ("pdst", (tc.p_pairs,), "i32"),
            ("py", (tc.p_pairs,), "f32"),
            ("pw", (tc.p_pairs,), "f32"),
        ]
    elif ds.multilabel:
        in_specs += [("y", (b, c), "f32"), ("wloss", (b,), "f32")]
    else:
        in_specs += [("y", (b,), "i32"), ("wloss", (b,), "f32")]
    cspecs = ctx_specs(ds, model, plans, b, k, train=True)
    in_specs += cspecs
    in_specs += [(f"param.{n}", s, "f32") for n, s in pspecs]

    out_specs = [("loss", (), "f32"), ("logits", (b, c), "f32")]
    for l, p in enumerate(plans):
        out_specs += [
            (f"l{l}.xfeat", (b, p.f_in), "f32"),
            (f"l{l}.gvec", (b, p.g_dim), "f32"),
            (f"l{l}.assign", (p.n_br, b), "i32"),
        ]
    out_specs += [(f"grad.{n}", s, "f32") for n, s in pspecs]

    n_layers = model.layers

    def fn(*flat):
        i = 0
        xb = flat[i]; i += 1
        if link:
            psrc, pdst, py, pw = flat[i:i + 4]; i += 4
        else:
            y = flat[i]; wl = flat[i + 1]; i += 2
        ctxs, whiten = [], []
        for p in plans:
            ctx, i = _layer_ctx(model, p, flat, i)
            whiten.append((flat[i], flat[i + 1], flat[i + 2]))
            i += 3
            ctxs.append(ctx)
        params_flat = list(flat[i:])
        assert len(params_flat) == len(pspecs)
        layer_params = unflatten_params(model, n_layers, params_flat)

        probes = [jnp.zeros((b, p.g_dim), jnp.float32) for p in plans]

        def loss_fn(params_flat, probes):
            lp = unflatten_params(model, n_layers, params_flat)
            outp, feats = _forward(model, plans, lp, ctxs, xb, probes)
            if link:
                loss, _ = link_loss(outp, psrc, pdst, py, pw)
            elif ds.multilabel:
                loss = bce_multilabel_loss(outp, y, wl)
            else:
                loss = ce_loss(outp, y, wl)
            return loss, (outp, feats)

        (loss, (logits, feats)), (gparams, gprobes) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(params_flat, probes)

        outs = [loss, logits]
        for l, p in enumerate(plans):
            mean, var, cww = whiten[l]
            assign, _z = _whiten_assign(p, feats[l], gprobes[l], mean, var, cww)
            outs += [feats[l], gprobes[l], assign]
        outs += list(gparams)
        return tuple(outs)

    return fn, in_specs, out_specs


def build_vq_infer(ds: DatasetCfg, model: ModelCfg, tc: TrainCfg,
                   b: int, k: int):
    """VQ-GNN mini-batch inference (Eq. 6 only). Emits logits/embeddings."""
    plans = make_plan(ds, model)
    pspecs = param_specs(ds, model)
    c = out_dim(ds, model)

    in_specs = [("xb", (b, ds.f_in_pad), "f32")]
    in_specs += ctx_specs(ds, model, plans, b, k, train=False)
    in_specs += [(f"param.{n}", s, "f32") for n, s in pspecs]
    out_specs = [("logits", (b, c), "f32")]
    # Per-layer input features: the inductive-inference path re-assigns
    # unseen nodes per layer from these (feature-masked vq_assign sweep).
    out_specs += [(f"l{l}.xfeat", (b, p.f_in), "f32")
                  for l, p in enumerate(plans)]
    n_layers = model.layers

    def fn(*flat):
        i = 0
        xb = flat[i]; i += 1
        ctxs = []
        for p in plans:
            ctx, i = _layer_ctx(model, p, flat, i)
            ctxs.append(ctx)
        layer_params = unflatten_params(model, n_layers, list(flat[i:]))
        probes = [jnp.zeros((b, p.g_dim), jnp.float32) for p in plans]
        outp, feats = _forward(model, plans, layer_params, ctxs, xb, probes)
        return tuple([outp] + feats)

    return fn, in_specs, out_specs


def build_vq_serve(ds: DatasetCfg, model: ModelCfg, tc: TrainCfg,
                   b: int, k: int):
    """Forward-only serving step (the `serve` read path).  Mirrors
    rust/src/runtime/builtin.rs::vq_serve_spec: logits only — no residual
    outputs, and the transposed (backward-only) sketches drop out of the
    signature entirely (the serving cache never builds them; they are fed
    as zeros to the shared forward, which never reads them)."""
    plans = make_plan(ds, model)
    pspecs = param_specs(ds, model)
    c = out_dim(ds, model)

    in_specs = [("xb", (b, ds.f_in_pad), "f32")]
    for l, p in enumerate(plans):
        pre = f"l{l}."
        if model.learnable_conv:
            in_specs += [
                (pre + "mask_in", (b, b), "f32"),
                (pre + "m_out", (b, k), "f32"),
            ]
            if model.name == "txf":
                in_specs += [(pre + "cnt_out", (k,), "f32")]
        else:
            in_specs += [
                (pre + "c_in", (b, b), "f32"),
                (pre + "c_out", (p.n_br, b, k), "f32"),
            ]
        in_specs += [(pre + "cw", (p.n_br, k, p.fp), "f32")]
    in_specs += [(f"param.{n}", s, "f32") for n, s in pspecs]
    out_specs = [("logits", (b, c), "f32")]
    n_layers = model.layers

    def fn(*flat):
        i = 0
        xb = flat[i]; i += 1
        ctxs = []
        for p in plans:
            ctx = {}
            if model.learnable_conv:
                ctx["mask_in"] = flat[i]; i += 1
                ctx["m_out"] = flat[i]; i += 1
                ctx["m_out_t"] = jnp.zeros((b, k), jnp.float32)
                if model.name == "txf":
                    ctx["cnt_out"] = flat[i]; i += 1
            else:
                ctx["c_in"] = flat[i]; i += 1
                ctx["c_out"] = flat[i]; i += 1
                ctx["ct_out"] = jnp.zeros((p.n_br, b, k), jnp.float32)
            ctx["cw"] = flat[i]; i += 1
            ctx["gcol"] = (p.f_in, p.g_dim)
            ctxs.append(ctx)
        layer_params = unflatten_params(model, n_layers, list(flat[i:]))
        probes = [jnp.zeros((b, p.g_dim), jnp.float32) for p in plans]
        outp, _feats = _forward(model, plans, layer_params, ctxs, xb, probes)
        return (outp,)

    return fn, in_specs, out_specs


def build_vq_assign_only(n_br: int, b: int, k: int, fp: int):
    """Standalone assignment artifact (inductive inference: unseen nodes are
    assigned by their *feature* columns only, via the mask input)."""
    in_specs = [
        ("z", (n_br, b, fp), "f32"),
        ("cww", (n_br, k, fp), "f32"),
        ("mask", (n_br, fp), "f32"),
    ]
    out_specs = [("assign", (n_br, b), "i32")]

    def fn(z, cww, mask):
        return (vq_assign(z, cww, mask),)

    return fn, in_specs, out_specs
