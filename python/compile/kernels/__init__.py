"""L1: Pallas kernels for the VQ-GNN compute hot-spots, plus their jnp oracle.

Kernels (all lowered with interpret=True — see /opt/xla-example/README.md):
  - appx_mp.fused_mp      fused [C_in | C_out~] message passing (Eq. 6/7)
  - vq_assign.vq_assign   nearest-codeword search (Alg. 2 FINDNEAREST)
  - gat_scores.gat_scores dense additive-attention tile with analytic VJP
"""

from . import ref  # noqa: F401
from .appx_mp import fused_mp  # noqa: F401
from .gat_scores import gat_scores  # noqa: F401
from .vq_assign import vq_assign  # noqa: F401
