"""L1 Pallas kernel: nearest-codeword assignment (the VQ codebook-update
hot-spot, paper Alg. 2 FINDNEAREST).

Distances are expanded as ‖z‖² − 2·z·X̃ᵀ + ‖X̃‖² so the dominant cost is a
(b, fp) × (fp, k) matmul per branch — MXU-friendly on TPU; the row-norm and
argmin ride along in the same VMEM tile.  Supports a per-dim mask so the
inductive-inference path can assign unseen nodes by feature columns only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(z_ref, cw_ref, mask_ref, o_ref):
    # z: (1, bt, fp); cw: (1, k, fp); mask: (1, fp) -> o: (1, bt)
    m = mask_ref[0]
    z = z_ref[0] * m[None, :]
    cw = cw_ref[0] * m[None, :]
    cross = jnp.dot(z, cw.T, preferred_element_type=jnp.float32)
    d = (
        (z * z).sum(axis=1)[:, None]
        - 2.0 * cross
        + (cw * cw).sum(axis=1)[None, :]
    )
    o_ref[0] = jnp.argmin(d, axis=1).astype(jnp.int32)


def _pick_bt(b: int) -> int:
    for bt in (256, 128, 64):
        if b % bt == 0:
            return bt
    return b


@functools.partial(jax.jit, static_argnames=("interpret",))
def vq_assign(z, cww, mask, interpret: bool = True):
    """Per-branch nearest-codeword assignment in the whitened space.

    z    : (B, b, fp) whitened mini-batch concat vectors
    cww  : (B, k, fp) whitened codewords
    mask : (B, fp)    1.0 for dims participating in the distance
    returns (B, b) int32
    """
    n_br, b, fp = z.shape
    k = cww.shape[1]
    bt = _pick_bt(b)
    grid = (n_br, b // bt)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, fp), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, k, fp), lambda j, i: (j, 0, 0)),
            pl.BlockSpec((1, fp), lambda j, i: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n_br, b), jnp.int32),
        interpret=interpret,
    )(z, cww, mask)


def vmem_footprint_bytes(b: int, k: int, fp: int) -> int:
    bt = _pick_bt(b)
    return 4 * (bt * fp + k * fp + fp + bt * k + bt)


def mxu_flops(b: int, k: int, n_br: int, fp: int) -> int:
    return n_br * b * k * fp
