"""L1 Pallas kernel: fused approximated message passing (paper Eq. 6/7 core).

Computes, over the padded concat space F = B*fp:

    out[:, j·fp:(j+1)·fp] = C_in @ X_pad[:, j·fp:(j+1)·fp] + C̃_out[j] @ X̃[j]

i.e. one fused (b, b)·(b, F) GEMM plus the per-branch sketch GEMMs.  The same
kernel serves the forward pass (Eq. 6: X_pad carries X_B in the feature
columns) and the backward pass (Eq. 7: X_pad carries G_B in the gradient
columns and the sketches are the transposed-convolution sketches).

TPU mapping (DESIGN.md §Hardware-Adaptation): grid = (b/bt, B); each step
keeps a (bt, b) slab of C_in, a (b, fp) slab of X_pad, a (bt, k) slab of the
branch sketch and the (k, fp) branch codebook in VMEM and issues two MXU
matmuls accumulating into a (bt, fp) output tile.  On this image the kernel
runs with interpret=True (CPU), which lowers to plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_mp_kernel(c_in_ref, x_ref, c_out_ref, cw_ref, o_ref):
    # c_in_ref: (bt, b); x_ref: (b, fp); c_out_ref: (1, bt, k); cw_ref: (1, k, fp)
    exact = jnp.dot(c_in_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    approx = jnp.dot(
        c_out_ref[0], cw_ref[0], preferred_element_type=jnp.float32
    )
    o_ref[...] = exact + approx


def _pick_bt(b: int) -> int:
    """Row-tile size: the largest of {128, 64, b} that divides b."""
    for bt in (256, 128, 64):
        if b % bt == 0:
            return bt
    return b


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_mp(c_in, x_pad, c_out, cw, interpret: bool = True):
    """Fused [C_in | C̃_out] @ [X_pad ; X̃] over the padded concat space.

    c_in : (b, b) f32   intra-mini-batch convolution block
    x_pad: (b, F) f32   batch vectors laid out over concat columns
    c_out: (B, b, k) f32 per-branch out-of-batch sketches
    cw   : (B, k, fp) f32 per-branch codewords
    returns (b, F) f32 with F = B*fp
    """
    b = c_in.shape[0]
    n_br, _, k = c_out.shape
    fp = cw.shape[2]
    assert x_pad.shape == (b, n_br * fp), (x_pad.shape, (b, n_br * fp))
    bt = _pick_bt(b)
    grid = (b // bt, n_br)
    return pl.pallas_call(
        _fused_mp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, b), lambda i, j: (i, 0)),
            pl.BlockSpec((b, fp), lambda i, j: (0, j)),
            pl.BlockSpec((1, bt, k), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, k, fp), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, fp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_br * fp), jnp.float32),
        interpret=interpret,
    )(c_in, x_pad, c_out, cw)


def vmem_footprint_bytes(b: int, k: int, n_br: int, fp: int) -> int:
    """Estimated VMEM residency per grid step (used by the §Perf analysis)."""
    bt = _pick_bt(b)
    return 4 * (bt * b + b * fp + bt * k + k * fp + bt * fp)


def mxu_flops(b: int, k: int, n_br: int, fp: int) -> int:
    """MXU MACs for one full fused_mp invocation."""
    return b * b * (n_br * fp) + n_br * b * k * fp
