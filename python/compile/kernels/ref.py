"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

Each function here is the mathematical definition the corresponding kernel in
this package must match (up to float tolerance).  pytest sweeps shapes/dtypes
via hypothesis and asserts allclose against these.
"""

from __future__ import annotations

import jax.numpy as jnp

# Attention scores are exp(LeakyReLU(·)) without max-subtraction (GAT);
# capped for numerical stability (part of the Lipschitz control, App. E).
SCORE_CAP = 8.0


def unsketch_ref(c_out, cw):
    """Out-of-batch message reconstruction: Σ_branches C̃_out[j] @ X̃[j]
    laid out over the padded concat (feature ‖ gradient) space.

    c_out: (B, b, k)  per-branch sketches C_out R_j
    cw   : (B, k, fp) per-branch codewords
    returns (b, B*fp) — caller slices feature vs gradient columns.
    """
    b = c_out.shape[1]
    n_br, _k, fp = cw.shape
    return jnp.einsum("jbv,jvp->bjp", c_out, cw).reshape(b, n_br * fp)


def appx_mp_ref(c_in, xb, c_out, cw):
    """Approximated forward message passing (paper Eq. 6, pre-weight half).

    out = C_in @ X_B  +  unsketch(C̃_out, X̃)[:, :f]

    c_in : (b, b) intra-mini-batch convolution block
    xb   : (b, f) mini-batch features
    """
    f = xb.shape[1]
    return c_in @ xb + unsketch_ref(c_out, cw)[:, :f]


def vq_assign_ref(z, cww):
    """Nearest-codeword assignment per branch (whitened space).

    z   : (B, b, fp) whitened mini-batch vectors per branch
    cww : (B, k, fp) whitened codewords per branch
    returns (B, b) int32 = argmin_v ||z - cww_v||²
    """
    d = (
        (z * z).sum(-1)[:, :, None]
        - 2.0 * jnp.einsum("jbp,jvp->jbv", z, cww)
        + (cww * cww).sum(-1)[:, None, :]
    )
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def vq_assign_masked_ref(z, cww, mask):
    """Assignment using only unmasked dims (inductive inference: the gradient
    half of the concat space is unknown for unseen nodes, so mask it out).

    mask: (B, fp) — 1.0 for dims that participate in the distance.
    """
    zm = z * mask[:, None, :]
    cm = cww * mask[:, None, :]
    d = (
        (zm * zm).sum(-1)[:, :, None]
        - 2.0 * jnp.einsum("jbp,jvp->jbv", zm, cm)
        + (cm * cm).sum(-1)[:, None, :]
    )
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def gat_scores_ref(e_src, e_dst, mask, slope: float = 0.2):
    """Additive (GAT) attention scores over a dense (b, b) tile.

    score[i, j] = mask[i, j] * exp(LeakyReLU(e_dst[i] + e_src[j]))

    Row i is the *target* (message receiver): the "query" half comes from the
    destination node's projection, matching GAT's a·[W x_i ‖ W x_j].
    """
    s = e_dst[:, None] + e_src[None, :]
    s = jnp.where(s >= 0, s, slope * s)
    return mask * jnp.exp(jnp.minimum(s, SCORE_CAP))


def segment_softmax_mp_ref(x, esrc, edst, escore, n: int):
    """Edge-list attention aggregation with segment-sum normalization
    (the full-graph / subgraph GAT oracle used by the baseline path).

    out[i] = Σ_{e: dst=i} escore[e]·x[src_e] / Σ_{e: dst=i} escore[e]
    """
    num = jnp.zeros((n, x.shape[1]), x.dtype).at[edst].add(escore[:, None] * x[esrc])
    den = jnp.zeros((n,), x.dtype).at[edst].add(escore)
    return num / jnp.maximum(den, 1e-12)[:, None]


def edge_mp_ref(x, esrc, edst, ecoef, n: int):
    """Plain edge-list message passing: out[i] = Σ_{e: dst=i} coef_e·x[src_e]."""
    return jnp.zeros((n, x.shape[1]), x.dtype).at[edst].add(ecoef[:, None] * x[esrc])
