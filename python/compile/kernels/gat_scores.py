"""L1 Pallas kernel: dense additive-attention score tile (GAT, paper Table 1).

score[i, j] = mask[i, j] · exp(LeakyReLU(e_dst[i] + e_src[j]))

The (b, b) score matrix is produced tile-by-tile from two rank-1 operands —
on TPU this is VPU (elementwise) work laid out so each (bt, bt) tile stays in
VMEM; the mask doubles as the adjacency pattern 𝔠 = A + I.

The exported entry point carries a hand-derived custom VJP (the analytic
gradient of the exp∘LeakyReLU outer sum) so the kernel sits on the training
hot path without relying on pallas autodiff.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SLOPE = 0.2
# Cap on the pre-exp score: bounds exp() and realizes the Lipschitz control
# of App. E (without it, unnormalized attention overflows in training).
SCORE_CAP = 8.0


def _scores_kernel(esrc_ref, edst_ref, mask_ref, o_ref):
    s = edst_ref[...][:, None] + esrc_ref[...][None, :]
    s = jnp.where(s >= 0, s, SLOPE * s)
    o_ref[...] = mask_ref[...] * jnp.exp(jnp.minimum(s, SCORE_CAP))


def _pick_bt(b: int) -> int:
    for bt in (256, 128, 64):
        if b % bt == 0:
            return bt
    return b


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gat_scores_fwd_kernel(e_src, e_dst, mask, interpret: bool = True):
    b = e_src.shape[0]
    bt = _pick_bt(b)
    grid = (b // bt, b // bt)
    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt,), lambda i, j: (j,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt, bt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bt, bt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=interpret,
    )(e_src, e_dst, mask)


@jax.custom_vjp
def gat_scores(e_src, e_dst, mask):
    """Dense GAT score tile with analytic backward.

    e_src: (b,) source-side projections a_src·(X W)
    e_dst: (b,) destination-side projections a_dst·(X W)
    mask : (b, b) fixed convolution mask 𝔠 (A + I restricted to the batch)
    """
    return _gat_scores_fwd_kernel(e_src, e_dst, mask)


def _fwd(e_src, e_dst, mask):
    s = gat_scores(e_src, e_dst, mask)
    return s, (e_src, e_dst, mask, s)


def _bwd(res, g):
    e_src, e_dst, mask, s = res
    raw = e_dst[:, None] + e_src[None, :]
    # d/draw exp(min(leaky(raw), CAP)) = s * leaky'(raw) * 1{leaky < CAP};
    # s already holds mask * exp(min(leaky(raw), CAP)).
    leaky = jnp.where(raw >= 0, raw, SLOPE * raw)
    slope_grad = jnp.where(raw >= 0, 1.0, SLOPE) * (leaky < SCORE_CAP)
    gs = g * s * slope_grad
    return gs.sum(axis=0), gs.sum(axis=1), None


gat_scores.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(b: int) -> int:
    bt = _pick_bt(b)
    return 4 * (2 * bt + 2 * bt * bt)
