"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT pipeline.

Never imported at runtime; `make artifacts` runs `python -m compile.aot` once
and the rust coordinator consumes artifacts/*.hlo.txt + manifest.json.
"""
