"""L2: exact edge-list message passing — the baseline compute path.

Full-graph training ("oracle" rows of Table 4), NS-SAGE, Cluster-GCN and
GraphSAINT all run standard exact message passing over a node set + edge
list; they differ only in *which* subgraph the coordinator feeds (and in the
SAINT normalization coefficients).  One artifact family serves them all:

  x     : (nn, f)  node features of the (sub)graph, padded to nn
  esrc  : (ne,)    source node index per directed edge (padded with 0)
  edst  : (ne,)    destination node index per directed edge
  ecoef : (ne,)    convolution coefficient per edge (0 ⇒ padding edge).
                   GCN: sym-norm D̃^{-1/2}ÃD̃^{-1/2} entries (incl. self loop
                   edges); SAGE: 1/deg(dst); SAINT: divided by α_e; GAT: edge
                   validity (attention computed in-graph).
  y, wloss        : labels and per-node loss weights (mask / λ_v weights)

Autodiff end-to-end — the baselines back-propagate exactly on the subgraph,
matching the sampling methods in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import DatasetCfg, ModelCfg, TrainCfg, out_dim
from .kernels.gat_scores import SCORE_CAP, SLOPE
from .layers import DEN_FLOOR
from .model import (bce_multilabel_loss, ce_loss, link_loss, param_specs,
                    unflatten_params)


def edge_mp(x, esrc, edst, ecoef, nn: int):
    """out[i] = Σ_{e: dst_e = i} ecoef_e · x[src_e] (scatter-add)."""
    return jnp.zeros((nn, x.shape[1]), x.dtype).at[edst].add(
        ecoef[:, None] * x[esrc]
    )


def _gat_edge_layer(params, x, esrc, edst, evalid, nn, heads):
    outs = []
    for s in range(heads):
        proj = x @ params["w"][s]
        e_src = proj @ params["a_src"][s]
        e_dst = proj @ params["a_dst"][s]
        raw = e_dst[edst] + e_src[esrc]
        raw = jnp.where(raw >= 0, raw, SLOPE * raw)
        score = evalid * jnp.exp(jnp.minimum(raw, SCORE_CAP))
        num = jnp.zeros((nn, proj.shape[1]), x.dtype).at[edst].add(
            score[:, None] * proj[esrc]
        )
        den = jnp.zeros((nn,), x.dtype).at[edst].add(score)
        outs.append(num / jnp.maximum(den, DEN_FLOOR)[:, None])
    return jnp.concatenate(outs, axis=1) + params["bias"]


def _edge_forward(model: ModelCfg, ds: DatasetCfg, layer_params, x,
                  esrc, edst, ecoef, nn: int):
    h = x
    n_layers = model.layers
    for l in range(n_layers):
        last = l == n_layers - 1
        p = layer_params[l]
        if model.name == "gcn":
            y = edge_mp(h, esrc, edst, ecoef, nn) @ p["w"] + p["bias"]
        elif model.name == "sage":
            y = h @ p["w_self"] + edge_mp(h, esrc, edst, ecoef, nn) @ p["w_nbr"] + p["bias"]
        elif model.name == "gat":
            heads = 1 if last else model.heads
            y = _gat_edge_layer(p, h, esrc, edst, ecoef, nn, heads)
        else:
            raise ValueError(f"edge path does not support {model.name}")
        h = y if last else jax.nn.relu(y)
    return h


def build_edge_train(ds: DatasetCfg, model: ModelCfg, tc: TrainCfg,
                     nn: int, ne: int):
    """Exact subgraph train step: loss + ∇params on a padded edge list."""
    pspecs = param_specs(ds, model)
    c = out_dim(ds, model)
    link = ds.task == "link"

    in_specs = [
        ("x", (nn, ds.f_in_pad), "f32"),
        ("esrc", (ne,), "i32"),
        ("edst", (ne,), "i32"),
        ("ecoef", (ne,), "f32"),
    ]
    if link:
        in_specs += [
            ("psrc", (tc.p_pairs,), "i32"),
            ("pdst", (tc.p_pairs,), "i32"),
            ("py", (tc.p_pairs,), "f32"),
            ("pw", (tc.p_pairs,), "f32"),
        ]
    elif ds.multilabel:
        in_specs += [("y", (nn, c), "f32"), ("wloss", (nn,), "f32")]
    else:
        in_specs += [("y", (nn,), "i32"), ("wloss", (nn,), "f32")]
    in_specs += [(f"param.{n}", s, "f32") for n, s in pspecs]

    out_specs = [("loss", (), "f32"), ("logits", (nn, c), "f32")]
    out_specs += [(f"grad.{n}", s, "f32") for n, s in pspecs]

    def fn(*flat):
        i = 0
        x, esrc, edst, ecoef = flat[i:i + 4]; i += 4
        if link:
            psrc, pdst, py, pw = flat[i:i + 4]; i += 4
        else:
            y, wl = flat[i:i + 2]; i += 2
        params_flat = list(flat[i:])

        def loss_fn(pf):
            lp = unflatten_params(model, model.layers, pf)
            outp = _edge_forward(model, ds, lp, x, esrc, edst, ecoef, nn)
            if link:
                loss, _ = link_loss(outp, psrc, pdst, py, pw)
            elif ds.multilabel:
                loss = bce_multilabel_loss(outp, y, wl)
            else:
                loss = ce_loss(outp, y, wl)
            return loss, outp

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params_flat)
        return tuple([loss, logits] + list(grads))

    return fn, in_specs, out_specs


def build_edge_infer(ds: DatasetCfg, model: ModelCfg, tc: TrainCfg,
                     nn: int, ne: int):
    """Exact forward pass over a (sub)graph — used for full-graph inference
    (layer-stacked) and the baselines' neighbor-expansion inference."""
    pspecs = param_specs(ds, model)
    c = out_dim(ds, model)
    in_specs = [
        ("x", (nn, ds.f_in_pad), "f32"),
        ("esrc", (ne,), "i32"),
        ("edst", (ne,), "i32"),
        ("ecoef", (ne,), "f32"),
    ]
    in_specs += [(f"param.{n}", s, "f32") for n, s in pspecs]
    out_specs = [("logits", (nn, c), "f32")]

    def fn(*flat):
        x, esrc, edst, ecoef = flat[:4]
        lp = unflatten_params(model, model.layers, list(flat[4:]))
        return (_edge_forward(model, ds, lp, x, esrc, edst, ecoef, nn),)

    return fn, in_specs, out_specs
