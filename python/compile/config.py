"""Single source of truth for dataset shapes, model dims and artifact specs.

Everything the rust coordinator needs to know about shapes is emitted into
``artifacts/manifest.json`` by ``aot.py``; the rust side never hard-codes a
dimension.  The synthetic dataset stand-ins (see DESIGN.md §3) are parameterized
here so the graph generators (rust) and the AOT shapes (python) can never drift.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Datasets (synthetic stand-ins for the paper's five benchmarks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DatasetCfg:
    name: str
    n: int                      # number of nodes
    m_max: int                  # padded directed-edge capacity (incl. self loops)
    f_in: int                   # raw input feature dim
    n_classes: int
    task: str                   # "node" | "link"
    multilabel: bool = False
    inductive: bool = False
    n_graphs: int = 1           # >1 => disjoint union (PPI-style inductive)
    avg_degree: float = 8.0     # generator target
    communities: int = 16       # planted communities (label signal)
    feature_noise: float = 1.0  # generator noise scale
    intra_p_scale: float = 12.0  # SBM intra/inter connectivity ratio

    @property
    def f_in_pad(self) -> int:
        """Input features padded to a multiple of 8 (product-VQ friendliness)."""
        return ((self.f_in + 7) // 8) * 8


DATASETS: dict[str, DatasetCfg] = {
    # Tiny config for fast unit/integration tests (not a paper benchmark).
    "tiny_sim": DatasetCfg(
        name="tiny_sim", n=256, m_max=4096, f_in=16, n_classes=4,
        task="node", avg_degree=6.0, communities=4,
    ),
    # ogbn-arxiv stand-in: sparse scale-free citation graph, transductive.
    "arxiv_sim": DatasetCfg(
        name="arxiv_sim", n=8192, m_max=163840, f_in=64, n_classes=16,
        task="node", avg_degree=7.0, communities=16,
    ),
    # Reddit stand-in: dense SBM, message-bound, high-dim features.
    "reddit_sim": DatasetCfg(
        name="reddit_sim", n=4096, m_max=262144, f_in=128, n_classes=16,
        task="node", avg_degree=50.0, communities=16,
    ),
    # PPI stand-in: disjoint graphs, multilabel, inductive.
    "ppi_sim": DatasetCfg(
        name="ppi_sim", n=4608, m_max=131072, f_in=56, n_classes=16,
        task="node", multilabel=True, inductive=True, n_graphs=12,
        avg_degree=14.0, communities=16,
    ),
    # ogbl-collab stand-in: link prediction with held-out positives.
    "collab_sim": DatasetCfg(
        name="collab_sim", n=8192, m_max=163840, f_in=64, n_classes=0,
        task="link", avg_degree=8.0, communities=32,
    ),
    # Flickr stand-in: mid-size, high-dim features, 7 classes.
    "flickr_sim": DatasetCfg(
        name="flickr_sim", n=4096, m_max=98304, f_in=104, n_classes=7,
        task="node", avg_degree=10.0, communities=7,
    ),
}


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """A GNN backbone under the generalized-convolution framework (Eq. 1)."""

    name: str                   # gcn | sage | gat | txf
    hidden: int = 64
    layers: int = 3
    heads: int = 2              # gat/txf attention heads
    # Product VQ: dimension of each VQ branch over the concat (feat ‖ grad)
    # space.  Learnable-convolution models use a single full-dim codebook
    # (fp == 0 sentinel => one branch spanning everything); see DESIGN.md §2.
    fp: int = 16

    @property
    def learnable_conv(self) -> bool:
        return self.name in ("gat", "txf")


MODELS: dict[str, ModelCfg] = {
    "gcn": ModelCfg(name="gcn"),
    "sage": ModelCfg(name="sage"),
    "gat": ModelCfg(name="gat", fp=0),
    "txf": ModelCfg(name="txf", fp=0),
}


# ---------------------------------------------------------------------------
# Training / VQ hyper-parameters (paper App. F defaults, scaled)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    b: int = 512                # mini-batch size (nodes)
    k: int = 128                # codebook size per branch
    lr: float = 3e-3            # RMSprop lr (paper: 3e-3)
    rms_alpha: float = 0.99     # RMSprop smoothing (paper: 0.99)
    gamma: float = 0.99         # VQ codeword EMA decay  (Alg. 2 γ)
    beta: float = 0.99          # whitening EMA decay    (Alg. 2 β)
    p_pairs: int = 1024         # link-prediction pairs per step
    weight_clip: float = 4.0    # Lipschitz control for attention params


TRAIN = TrainCfg()


# ---------------------------------------------------------------------------
# Derived shapes
# ---------------------------------------------------------------------------


def feat_dims(ds: DatasetCfg, model: ModelCfg) -> list[int]:
    """Per-layer input feature dims [f_0 .. f_{L-1}] plus output dim f_L."""
    return [ds.f_in_pad] + [model.hidden] * model.layers


def branch_layout(f_l: int, h_l: int, fp: int) -> tuple[int, int]:
    """(num_branches, padded_concat_dim) for a layer with f_l input features
    and h_l pre-activation output dims.  fp == 0 => single branch."""
    concat = f_l + h_l
    if fp == 0:
        return 1, concat
    n_br = (concat + fp - 1) // fp
    return n_br, n_br * fp


def out_dim(ds: DatasetCfg, model: ModelCfg) -> int:
    if ds.task == "link":
        return model.hidden          # embeddings; pair scoring on top
    return ds.n_classes


# Subgraph artifact size classes for the sampling baselines.  A sampler picks
# the smallest class its batch fits into; the harness records which.
SUBGRAPH_SHAPES: dict[str, tuple[int, int]] = {
    "sub_s": (512, 16384),
    "sub_m": (1024, 49152),
    "sub_l": (2048, 98304),
    "sub_xl": (4096, 262144),
}


# Ablation grids (paper App. G), run on arxiv_sim + GCN.
ABLATION_LAYERS = [1, 2, 3, 4, 5]
ABLATION_CODEBOOK = [32, 64, 128, 256]
ABLATION_BATCH = [128, 256, 512, 1024]
