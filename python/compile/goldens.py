"""Golden-output generator: executes selected artifacts *in python* with
seeded inputs and dumps raw tensors, so the rust runtime can prove that its
PJRT load-compile-execute path reproduces jax numerics bit-for-bit-ish.

Writes artifacts/goldens/<artifact>/{index.json, <tensor>.bin} with f32/i32
little-endian raw payloads.  Run once via `make artifacts` (cheap).
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from . import aot
from .config import DATASETS, MODELS, TRAIN

GOLDEN_ARTIFACTS = [
    "vq_train_tiny_sim_gcn",
    "vq_train_tiny_sim_sage",
    "vq_train_tiny_sim_gat",
    "vq_infer_tiny_sim_gcn",
    "edge_train_tiny_sim_gcn_full",
    "vq_assign_tiny_sim",
]


def seeded_input(name: str, shape, dtype: str, rng: np.random.RandomState,
                 art: dict):
    """Deterministic pseudo-realistic inputs per tensor role."""
    ds = DATASETS[art["dataset"]]
    if dtype == "i32":
        if name == "y":
            return rng.randint(0, max(ds.n_classes, 2), shape).astype(np.int32)
        hi = shape[0] if not shape else (art.get("b") or art.get("nn") or 2)
        return rng.randint(0, max(hi, 2), shape).astype(np.int32)
    if name == "wloss" or name.endswith(".var") or name == "pw":
        return np.ones(shape, np.float32)
    if name in ("ecoef", "py"):
        return (rng.rand(*shape) < 0.5).astype(np.float32) * 0.25
    if ".c_in" in name or ".mask_in" in name:
        b = shape[0]
        m = (rng.rand(*shape) < 0.05).astype(np.float32)
        m[np.arange(b), np.arange(b)] = 1.0
        return (m * 0.2).astype(np.float32)
    if ".c_out" in name or ".ct_out" in name or ".m_out" in name:
        return ((rng.rand(*shape) < 0.03) * 0.2).astype(np.float32)
    return (rng.randn(*shape) * 0.3).astype(np.float32)


def main() -> None:
    out_root = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "goldens")
    registry = {a["name"]: a for a in aot.artifact_registry()}
    for art_name in GOLDEN_ARTIFACTS:
        art = registry[art_name]
        (fn, in_specs, out_specs), _mo = aot.build_fn(art)
        rng = np.random.RandomState(42)
        vals = [seeded_input(n, s, d, rng, art) for n, s, d in in_specs]
        outs = fn(*[jnp.array(v) for v in vals])
        d = os.path.join(out_root, art_name)
        os.makedirs(d, exist_ok=True)
        index = {"artifact": art_name, "inputs": [], "outputs": []}
        for (n, s, dt), v in zip(in_specs, vals):
            fname = "in_" + n.replace("/", "_") + ".bin"
            np.asarray(v).tofile(os.path.join(d, fname))
            index["inputs"].append(dict(name=n, shape=list(s), dtype=dt,
                                        file=fname))
        for (n, s, dt), v in zip(out_specs, outs):
            fname = "out_" + n.replace("/", "_") + ".bin"
            np.asarray(v).astype(
                np.int32 if dt == "i32" else np.float32
            ).tofile(os.path.join(d, fname))
            index["outputs"].append(dict(name=n, shape=list(s), dtype=dt,
                                         file=fname))
        with open(os.path.join(d, "index.json"), "w") as f:
            json.dump(index, f, indent=1)
        print(f"golden: {art_name} ({len(vals)} in / {len(outs)} out)")


if __name__ == "__main__":
    main()
