"""L2: generalized graph convolution layers with VQ-approximated message
passing (paper Eqs. 6 & 7).

The core primitive is :func:`mp_linear` — a custom-VJP boundary implementing
one convolution support `(C X) W` of Eq. 1 under the mini-batch + codebook
approximation:

  forward  (Eq. 6):  y = (C_in X_B + unsketch_feat(C̃_out, X̃)) W
  backward (Eq. 7):  ∇X_B = (C_inᵀ G_B + unsketch_grad((C̃ᵀ)_out, G̃)) Wᵀ

Both directions are the *same* fused L1 kernel (`kernels.fused_mp`): the
backward call feeds `C_inᵀ` and places the incoming gradient in the gradient
columns of the padded concat space, so the "blue" out-of-batch messages of
paper Fig. 2 ride in through the gradient half of the codewords.

The weight gradient `∇W = Mᵀ G_B` is exact given the approximated features
(paper App. C), and the convolution-matrix cotangents (∂ℓ/∂C_in, ∂ℓ/∂C̃_out)
are returned so learnable convolutions (GAT / Graph Transformer) train their
attention parameters through both the exact and approximated message paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.appx_mp import fused_mp
from .kernels.gat_scores import SCORE_CAP, SLOPE, gat_scores


def _pad_cols(x, width: int, offset: int = 0):
    """Place x into columns [offset, offset+x.shape[1]) of a (b, width) zero
    buffer (the concat-space layout used by the fused kernel)."""
    b, f = x.shape
    out = jnp.zeros((b, width), x.dtype)
    return jax.lax.dynamic_update_slice(out, x, (0, offset))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def mp_linear(gcol: tuple, xb, w, c_in, c_out, ct_out, cw):
    """One convolution support of Eq. 1 under approximated message passing.

    gcol  : static (start, width) — which gradient columns of the concat
            space this support consumes in the backward pass (multi-head
            attention slices its own head's columns).
    xb    : (b, f)       mini-batch features
    w     : (f, h)       layer weight for this support
    c_in  : (b, b)       intra-batch convolution block
    c_out : (B, b, k)    out-of-batch sketches C_out R (forward)
    ct_out: (B, b, k)    transposed-conv sketches (Cᵀ)_out R (backward)
    cw    : (B, k, fp)   concat-space codewords X̃ ‖ G̃
    """
    y, _ = _mp_linear_fwd(gcol, xb, w, c_in, c_out, ct_out, cw)
    return y


def _mp_linear_fwd(gcol, xb, w, c_in, c_out, ct_out, cw):
    b, f = xb.shape
    n_br, k, fp = cw.shape
    width = n_br * fp
    full = fused_mp(c_in, _pad_cols(xb, width), c_out, cw)
    m = full[:, :f]
    y = m @ w
    return y, (xb, w, c_in, ct_out, cw, m)


def _mp_linear_bwd(gcol, res, g):
    xb, w, c_in, ct_out, cw, m = res
    b, f = xb.shape
    n_br, k, fp = cw.shape
    width = n_br * fp
    gstart, gwidth = gcol
    # Approximated backward message passing (Eq. 7): feed C_inᵀ and the
    # incoming gradient (placed in this support's gradient columns) through
    # the same fused kernel; the codeword half contributes (C̃ᵀ)_out G̃.
    ubwd = fused_mp(
        jnp.transpose(c_in), _pad_cols(g, width, gstart), ct_out, cw
    )
    gslice = jax.lax.dynamic_slice(ubwd, (0, gstart), (b, gwidth))
    dxb = gslice @ w.T
    dw = m.T @ g
    # Convolution cotangents (pruned by XLA for fixed-convolution backbones).
    dm = g @ w.T
    dc_in = dm @ xb.T
    dmfull = _pad_cols(dm, width)
    dc_out = jnp.einsum(
        "bjp,jvp->jbv", dmfull.reshape(b, n_br, fp), cw
    )
    return dxb, dw, dc_in, dc_out, None, None


mp_linear.defvjp(_mp_linear_fwd, _mp_linear_bwd)


# ---------------------------------------------------------------------------
# Backbone layers.  Each takes the layer's VQ context (sketches + codewords)
# and a probe (zeros; its gradient is exactly G_B^{l+1}, captured by the
# training step for the codebook update).
# ---------------------------------------------------------------------------


def gcn_layer(params, ctx, xb, probe):
    """GCN (Table 1): single fixed support C = D̃^{-1/2} Ã D̃^{-1/2}."""
    h = mp_linear(
        ctx["gcol"], xb, params["w"], ctx["c_in"], ctx["c_out"],
        ctx["ct_out"], ctx["cw"],
    )
    return h + params["bias"] + probe


def sage_layer(params, ctx, xb, probe):
    """SAGE-Mean (Table 1): identity support + row-normalized D^{-1}A.

    The identity support needs no approximation (C_in = I_b, C_out = 0), so
    it is a plain dense product; only the mean aggregator goes through the
    approximated message-passing boundary.
    """
    h_self = xb @ params["w_self"]
    h_nbr = mp_linear(
        ctx["gcol"], xb, params["w_nbr"], ctx["c_in"], ctx["c_out"],
        ctx["ct_out"], ctx["cw"],
    )
    return h_self + h_nbr + params["bias"] + probe


# Attention-mass floor: exp(-SCORE_CAP), the cap's reciprocal.  Without it a
# destination whose every score underflows divides by ~0 and the probe
# gradient ∂ℓ/∂num explodes by up to 1e12 — the floor keeps the decoupled
# normalization Lipschitz (App. E) on both sides of the cap.
DEN_FLOOR = jnp.exp(-SCORE_CAP)


def _leaky_exp(s):
    return jnp.exp(jnp.minimum(jnp.where(s >= 0, s, SLOPE * s), SCORE_CAP))


def gat_layer(params, ctx, xb, probe, heads: int):
    """GAT (Table 1) under the decoupled row-normalization trick (App. E).

    Per head s with projection W_s and attention vectors a_src/a_dst:
      unnormalized score  s_ij = exp(LeakyReLU(e_dst_i + e_src_j)),
      in-batch block via the L1 `gat_scores` kernel, out-of-batch block via
      codeword projections weighted by the masked count sketches M_out /
      M_outᵀ supplied by the coordinator.  Numerator goes through
      `mp_linear`; the denominator is the same attention applied to 1s —
      i.e. plain row sums of the (approximate) convolution matrix.

    The probe is injected at the *unnormalized* numerator, so the captured
    gradient codewords pair with ∂ℓ/∂num — the quantity Eq. 7 needs at this
    boundary under the decoupled normalization (see DESIGN.md §2).
    """
    b, f = xb.shape
    cw = ctx["cw"]                       # (1, k, F) single-branch codebook
    cw_feat = cw[0, :, :f]               # feature half X̃ (k, f)
    hh = params["w"][0].shape[1]         # per-head out dim
    outs = []
    for s in range(heads):
        w_s = params["w"][s]
        proj = xb @ w_s                  # (b, hh)
        e_src = proj @ params["a_src"][s]
        e_dst = proj @ params["a_dst"][s]
        cproj = cw_feat @ w_s            # codeword projections (k, hh)
        ecw_src = cproj @ params["a_src"][s]
        ecw_dst = cproj @ params["a_dst"][s]
        # In-batch unnormalized scores on the fixed mask 𝔠 = A + I (Eq. 2).
        c_in = gat_scores(e_src, e_dst, ctx["mask_in"])
        # Out-of-batch: merged messages from codeword v (paper Fig. 1) carry
        # weight M_out[i,v]·h(X_i, X̃_v); transposed side mirrors it.
        c_out = (ctx["m_out"] * _leaky_exp(e_dst[:, None] + ecw_src[None, :]))[None]
        ct_out = (ctx["m_out_t"] * _leaky_exp(ecw_dst[None, :] + e_src[:, None]))[None]
        hh0 = s * hh
        num = mp_linear(
            (f + hh0, hh), xb, w_s, c_in, c_out, ct_out, cw
        ) + jax.lax.dynamic_slice(probe, (0, hh0), (b, hh))
        den = c_in.sum(axis=1) + c_out[0].sum(axis=1)
        outs.append(num / jnp.maximum(den, DEN_FLOOR)[:, None])
    return jnp.concatenate(outs, axis=1) + params["bias"]


def txf_layer(params, ctx, xb, probe, heads: int):
    """Graph-Transformer hybrid (paper Table 8): local GAT attention +
    global scaled-dot attention + a linear branch, summed.

    Global attention has 𝔠 = all-ones (App. Table 5): every out-of-batch
    node contributes, so the sketch weight for codeword v is simply the
    out-of-batch member count `cnt_out[v]` times the attention kernel
    evaluated against the codeword.

    The gradient half of the concat space is 2h wide: cols [f, f+h) hold the
    local-attention numerator gradients, cols [f+h, f+2h) the global ones.
    The probe is (b, 2h), split accordingly.
    """
    b, f = xb.shape
    cw = ctx["cw"]
    cw_feat = cw[0, :, :f]
    h = params["w_lin"].shape[1]
    local = gat_layer(
        {k: params[k] for k in ("w", "a_src", "a_dst", "bias")},
        ctx, xb, probe[:, :h], heads,
    )
    # Global attention branch (single head).
    dk = params["wq"].shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dk))
    q = xb @ params["wq"]
    kk = xb @ params["wk"]
    kcw = cw_feat @ params["wk"]
    qcw = cw_feat @ params["wq"]
    c_in = jnp.exp(jnp.minimum(scale * (q @ kk.T), SCORE_CAP))
    c_out = (ctx["cnt_out"][None, :] * jnp.exp(jnp.minimum(scale * (q @ kcw.T), SCORE_CAP)))[None]
    ct_out = (ctx["cnt_out"][None, :] * jnp.exp(jnp.minimum(scale * (qcw @ kk.T), SCORE_CAP)).T)[None]
    num = mp_linear(
        (f + h, h), xb, params["wv"], c_in, c_out, ct_out, cw
    ) + probe[:, h:]
    den = c_in.sum(axis=1) + c_out[0].sum(axis=1)
    glob = num / jnp.maximum(den, DEN_FLOOR)[:, None]
    lin = xb @ params["w_lin"]
    return local + glob + lin
