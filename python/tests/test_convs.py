"""Table 1 / App. Table 5: backbone layers really implement their
generalized-convolution formulas.  Each edge-list layer is checked against a
dense materialization of its convolution matrix."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import edgemp
from compile.config import DATASETS, MODELS
from compile.kernels.gat_scores import SCORE_CAP, SLOPE

RNG = np.random.RandomState


def _graph(rng, n, p=0.2, sym=False):
    """Random (di)graph + self loops; returns (adj bool (n,n), esrc, edst)."""
    adj = rng.rand(n, n) < p
    if sym:
        adj = adj | adj.T
    np.fill_diagonal(adj, False)
    src, dst = np.nonzero(adj)
    return adj, src.astype(np.int32), dst.astype(np.int32)


def test_gcn_conv_is_symnorm_adjacency():
    """C = D̃^{-1/2} Ã D̃^{-1/2} (Table 1, row GCN) on an undirected graph."""
    rng = RNG(0)
    n, f = 30, 8
    adj, src, dst = _graph(rng, n, sym=True)
    x = rng.randn(n, f).astype(np.float32)
    # Ã = A + I; coefficient per edge computed like the rust generator does.
    a_tilde = adj.astype(np.float32) + np.eye(n, dtype=np.float32)
    deg = a_tilde.sum(1)
    C = a_tilde / np.sqrt(deg[:, None] * deg[None, :])
    # Edge list with self loops; coefficient = C entries. NOTE the layer
    # aggregates over *incoming* edges (dst receives), so coef of edge
    # (s -> d) is C[d, s].
    es = np.concatenate([src, np.arange(n, dtype=np.int32)])
    ed = np.concatenate([dst, np.arange(n, dtype=np.int32)])
    coef = C[ed, es].astype(np.float32)
    got = np.asarray(edgemp.edge_mp(jnp.array(x), jnp.array(es),
                                    jnp.array(ed), jnp.array(coef), n))
    np.testing.assert_allclose(got, C @ x, rtol=1e-4, atol=1e-5)


def test_sage_conv_is_row_normalized_mean():
    """C^(2) = D^{-1} A (Table 1, row SAGE-Mean): mean over in-neighbors."""
    rng = RNG(1)
    n, f = 25, 6
    adj, src, dst = _graph(rng, n, p=0.3)
    x = rng.randn(n, f).astype(np.float32)
    deg_in = np.maximum(adj.sum(0), 1)  # in-degree of dst
    coef = (1.0 / deg_in[dst]).astype(np.float32)
    got = np.asarray(edgemp.edge_mp(jnp.array(x), jnp.array(src),
                                    jnp.array(dst), jnp.array(coef), n))
    C = adj.T.astype(np.float32) / np.maximum(adj.T.sum(1, keepdims=True), 1)
    np.testing.assert_allclose(got, C @ x, rtol=1e-4, atol=1e-5)


def test_gat_edge_layer_matches_dense_attention():
    """GAT (Table 1): C_ij = 𝔠_ij · exp(LeakyReLU(a·[Wx_i ‖ Wx_j])) with
    row-wise normalization; 𝔠 = A + I."""
    rng = RNG(2)
    n, f, hh = 20, 8, 5
    adj, src, dst = _graph(rng, n, p=0.25)
    x = rng.randn(n, f).astype(np.float32)
    w = (rng.randn(1, f, hh) / np.sqrt(f)).astype(np.float32)
    a_src = rng.randn(1, hh).astype(np.float32)
    a_dst = rng.randn(1, hh).astype(np.float32)
    bias = np.zeros(hh, np.float32)
    es = np.concatenate([src, np.arange(n, dtype=np.int32)])
    ed = np.concatenate([dst, np.arange(n, dtype=np.int32)])
    valid = np.ones(len(es), np.float32)
    params = {"w": jnp.array(w), "a_src": jnp.array(a_src),
              "a_dst": jnp.array(a_dst), "bias": jnp.array(bias)}
    got = np.asarray(edgemp._gat_edge_layer(
        params, jnp.array(x), jnp.array(es), jnp.array(ed), jnp.array(valid),
        n, heads=1))

    proj = x @ w[0]
    e_s, e_d = proj @ a_src[0], proj @ a_dst[0]
    mask = (adj | np.eye(n, dtype=bool)).astype(np.float32)
    # incoming edges: receiver i aggregates from j where adj[j, i] (j -> i)
    raw = e_d[:, None] + e_s[None, :]
    raw = np.where(raw >= 0, raw, SLOPE * raw)
    S = mask.T * np.exp(np.minimum(raw, SCORE_CAP))  # S[i,j]: weight j -> i
    S = S / np.maximum(S.sum(1, keepdims=True), 1e-12)
    np.testing.assert_allclose(got, S @ proj, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("model_name", ["gcn", "sage", "gat"])
def test_edge_train_step_gradients_descend(model_name):
    """One edge-train step's gradients reduce the loss when applied."""
    ds = DATASETS["tiny_sim"]
    model = MODELS[model_name]
    nn, ne = 64, 512
    fn, ins, outs = edgemp.build_edge_train(ds, model, None, nn, ne)
    rng = RNG(3)
    vals = []
    for name, shape, dt in ins:
        if name == "y":
            vals.append(jnp.array(rng.randint(0, ds.n_classes, shape)
                                  .astype(np.int32)))
        elif dt == "i32":
            vals.append(jnp.array(rng.randint(0, nn, shape).astype(np.int32)))
        elif name == "ecoef":
            vals.append(jnp.array((rng.rand(*shape) < 0.5).astype(np.float32) * 0.2))
        elif name == "wloss":
            vals.append(jnp.ones(shape, jnp.float32))
        else:
            vals.append(jnp.array(rng.randn(*shape).astype(np.float32) * 0.3))
    res = fn(*vals)
    loss0 = float(res[0])
    n_params = len([n for n, _, _ in ins if n.startswith("param.")])
    grads = res[-n_params:]
    lr = 0.05
    vals2 = list(vals)
    for i, g in zip(range(len(vals) - n_params, len(vals)), grads):
        vals2[i] = vals[i] - lr * g
    loss1 = float(fn(*vals2)[0])
    assert loss1 < loss0, (loss0, loss1)
