"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes with hypothesis (the CORE correctness signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_mp, gat_scores, ref, vq_assign
from compile.kernels.appx_mp import mxu_flops, vmem_footprint_bytes

RNG = np.random.RandomState


def _rand(rng, *shape):
    return rng.randn(*shape).astype(np.float32)


shape_strategy = st.tuples(
    st.sampled_from([64, 128, 192, 256]),   # b
    st.sampled_from([8, 16, 32, 64]),       # k
    st.sampled_from([4, 8, 16]),            # fp
    st.integers(min_value=1, max_value=6),  # branches
    st.integers(min_value=0, max_value=10_000),  # seed
)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_fused_mp_matches_ref(cfg):
    b, k, fp, n_br, seed = cfg
    rng = RNG(seed)
    c_in = _rand(rng, b, b)
    x = _rand(rng, b, n_br * fp)
    c_out = _rand(rng, n_br, b, k)
    cw = _rand(rng, n_br, k, fp)
    got = np.asarray(fused_mp(c_in, x, c_out, cw))
    want = np.asarray(c_in @ x + ref.unsketch_ref(jnp.array(c_out), jnp.array(cw)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_vq_assign_matches_ref(cfg):
    b, k, fp, n_br, seed = cfg
    rng = RNG(seed)
    z = _rand(rng, n_br, b, fp)
    cw = _rand(rng, n_br, k, fp)
    mask = np.ones((n_br, fp), np.float32)
    got = np.asarray(vq_assign(z, cw, mask))
    want = np.asarray(ref.vq_assign_ref(jnp.array(z), jnp.array(cw)))
    assert got.shape == (n_br, b)
    assert got.dtype == np.int32
    # argmin ties can differ across implementations only at exact distance
    # equality, which has measure zero for gaussian inputs.
    np.testing.assert_array_equal(got, want)


def test_vq_assign_mask_excludes_dims():
    """Masked dims must not influence the assignment (inductive inference)."""
    rng = RNG(0)
    z = _rand(rng, 2, 64, 8)
    cw = _rand(rng, 2, 16, 8)
    mask = np.ones((2, 8), np.float32)
    mask[:, 4:] = 0.0
    got = np.asarray(vq_assign(z, cw, mask))
    # corrupt the masked dims: result must be unchanged
    z2 = z.copy()
    z2[:, :, 4:] = 1e3
    got2 = np.asarray(vq_assign(z2, cw, mask))
    np.testing.assert_array_equal(got, got2)
    want = np.asarray(ref.vq_assign_masked_ref(
        jnp.array(z), jnp.array(cw), jnp.array(mask)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.integers(0, 1000), st.floats(0.02, 0.5))
def test_gat_scores_matches_ref(b, seed, density):
    rng = RNG(seed)
    e_src = _rand(rng, b)
    e_dst = _rand(rng, b)
    mask = (rng.rand(b, b) < density).astype(np.float32)
    got = np.asarray(gat_scores(e_src, e_dst, mask))
    want = np.asarray(ref.gat_scores_ref(
        jnp.array(e_src), jnp.array(e_dst), jnp.array(mask)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_gat_scores_gradient_matches_autodiff():
    """The hand-derived VJP must equal autodiff of the oracle."""
    import jax
    rng = RNG(3)
    b = 64
    e_src = jnp.array(_rand(rng, b))
    e_dst = jnp.array(_rand(rng, b))
    mask = jnp.array((rng.rand(b, b) < 0.2).astype(np.float32))

    def f_kernel(es, ed):
        return (gat_scores(es, ed, mask) * w).sum()

    def f_ref(es, ed):
        return (ref.gat_scores_ref(es, ed, mask) * w).sum()

    w = jnp.array(_rand(rng, b, b))
    g1 = jax.grad(f_kernel, argnums=(0, 1))(e_src, e_dst)
    g2 = jax.grad(f_ref, argnums=(0, 1))(e_src, e_dst)
    for a, bb in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-4, atol=1e-5)


def test_fused_mp_nonmultiple_tile_sizes():
    """Row counts that don't divide the preferred tile still work."""
    rng = RNG(7)
    b, k, fp, n_br = 96, 8, 4, 2   # 96 not divisible by 64
    c_in = _rand(rng, b, b)
    x = _rand(rng, b, n_br * fp)
    c_out = _rand(rng, n_br, b, k)
    cw = _rand(rng, n_br, k, fp)
    got = np.asarray(fused_mp(c_in, x, c_out, cw))
    want = np.asarray(c_in @ x + ref.unsketch_ref(jnp.array(c_out), jnp.array(cw)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_footprint_within_tpu_budget():
    """The production BlockSpec must fit a 16 MiB VMEM (DESIGN.md §Perf)."""
    assert vmem_footprint_bytes(b=512, k=128, n_br=8, fp=16) < 16 * 2**20
    assert mxu_flops(b=512, k=128, n_br=8, fp=16) > 0
