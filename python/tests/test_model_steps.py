"""End-to-end L2 checks: the assembled VQ train/infer steps execute, emit
the manifest-declared shapes, descend the loss, and behave consistently
under the exactness limit at the whole-model level."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.config import DATASETS, MODELS, TRAIN
from compile.model import build_vq_infer, build_vq_train, make_plan

RNG = np.random.RandomState


def _mk_inputs(in_specs, art, seed=0):
    from compile.goldens import seeded_input
    rng = RNG(seed)
    return [seeded_input(n, s, d, rng, art) for n, s, d in in_specs]


@pytest.mark.parametrize("model_name", ["gcn", "sage", "gat"])
def test_vq_train_step_shapes_and_descent(model_name):
    ds = DATASETS["tiny_sim"]
    model = MODELS[model_name]
    b, k = 64, 16
    art = dict(dataset="tiny_sim", model=model_name, b=b, k=k)
    fn, ins, outs = build_vq_train(ds, model, TRAIN, b, k)
    vals = _mk_inputs(ins, art)
    res = fn(*[jnp.array(v) for v in vals])
    assert len(res) == len(outs)
    for (name, shape, dt), v in zip(outs, res):
        assert tuple(np.asarray(v).shape) == tuple(shape), (name, shape)
        want_dt = np.int32 if dt == "i32" else np.float32
        assert np.asarray(v).dtype == want_dt, name
        assert np.isfinite(np.asarray(v)).all() if dt == "f32" else True, name

    # assignments must be within [0, k)
    for (name, _, _), v in zip(outs, res):
        if name.endswith(".assign"):
            a = np.asarray(v)
            assert (a >= 0).all() and (a < k).all()

    # applying the returned gradients reduces the loss.  With *random*
    # gradient codewords the Eq. 7 blue-message terms are noise, so for the
    # descent check we zero the transposed sketches — the custom backward
    # then equals the exact gradient of the approximated forward.
    for i, (n, _, _) in enumerate(ins):
        if n.endswith(".ct_out") or n.endswith(".m_out_t"):
            vals[i] = np.zeros_like(vals[i])
    res = fn(*[jnp.array(v) for v in vals])
    loss0 = float(res[0])
    n_params = sum(1 for n, _, _ in ins if n.startswith("param."))
    grads = res[-n_params:]
    vals2 = list(vals)
    off = len(ins) - n_params
    for i, g in enumerate(grads):
        vals2[off + i] = vals[off + i] - 0.005 * np.asarray(g)
    loss1 = float(fn(*[jnp.array(v) for v in vals2])[0])
    assert loss1 < loss0, (model_name, loss0, loss1)


@pytest.mark.parametrize("model_name", ["gcn", "sage", "gat"])
def test_vq_infer_matches_train_forward(model_name):
    """The infer artifact must agree with the train artifact's logits when
    fed the same forward inputs."""
    ds = DATASETS["tiny_sim"]
    model = MODELS[model_name]
    b, k = 64, 16
    art = dict(dataset="tiny_sim", model=model_name, b=b, k=k)
    fn_t, ins_t, outs_t = build_vq_train(ds, model, TRAIN, b, k)
    fn_i, ins_i, outs_i = build_vq_infer(ds, model, TRAIN, b, k)
    vals_t = _mk_inputs(ins_t, art)
    by_name = {n: v for (n, _, _), v in zip(ins_t, vals_t)}
    vals_i = [by_name[n] for n, _, _ in ins_i]
    logits_t = np.asarray(fn_t(*[jnp.array(v) for v in vals_t])[1])
    logits_i = np.asarray(fn_i(*[jnp.array(v) for v in vals_i])[0])  # first output
    np.testing.assert_allclose(logits_i, logits_t, rtol=1e-4, atol=1e-5)


def test_link_prediction_head():
    ds = DATASETS["collab_sim"]
    model = MODELS["gcn"]
    b, k = 64, 16
    small = dataclasses.replace(ds, n=256, m_max=4096)
    art = dict(dataset="collab_sim", model="gcn", b=b, k=k)
    fn, ins, outs = build_vq_train(small, model, TRAIN, b, k)
    names = [n for n, _, _ in ins]
    assert "psrc" in names and "py" in names
    vals = _mk_inputs(ins, art)
    res = fn(*[jnp.array(v) for v in vals])
    assert np.isfinite(float(res[0]))
    # logits output is the (b, hidden) embedding table for pair scoring
    assert np.asarray(res[1]).shape == (b, model.hidden)


def test_multilabel_head():
    ds = DATASETS["ppi_sim"]
    model = MODELS["gcn"]
    b, k = 64, 16
    art = dict(dataset="ppi_sim", model="gcn", b=b, k=k)
    fn, ins, outs = build_vq_train(ds, model, TRAIN, b, k)
    yspec = next(s for n, s, d in ins if n == "y")
    assert yspec == (b, ds.n_classes)
    vals = _mk_inputs(ins, art)
    res = fn(*[jnp.array(v) for v in vals])
    assert np.isfinite(float(res[0]))


def test_manifest_registry_is_consistent():
    """Every artifact in the registry resolves to a builder whose specs have
    positive static shapes and unique names."""
    arts = aot.artifact_registry()
    names = [a["name"] for a in arts]
    assert len(names) == len(set(names))
    # spot-check a handful across kinds without lowering
    for a in arts[::7]:
        (fn, ins, outs), _ = aot.build_fn(a)
        for n, s, d in ins + outs:
            assert all(int(x) > 0 for x in s) or s == (), (a["name"], n, s)
        in_names = [n for n, _, _ in ins]
        assert len(in_names) == len(set(in_names)), a["name"]


def test_plan_branch_layout_covers_concat_space():
    for ds_name in ("tiny_sim", "arxiv_sim", "reddit_sim"):
        ds = DATASETS[ds_name]
        for mname, model in MODELS.items():
            for p in make_plan(ds, model):
                assert p.n_br * p.fp == p.F
                assert p.F >= p.f_in + p.g_dim
                assert p.F - (p.f_in + p.g_dim) < max(p.fp, 1)
