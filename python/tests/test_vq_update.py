"""Alg. 2 (VQ-Update) reference semantics: EMA invariants, whitening
round-trip, and convergence of the online k-means behaviour."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.vq import EPS, VqState, assign, vq_update

RNG = np.random.RandomState


def test_whitening_roundtrip():
    st_ = VqState.init(8, 4, seed=1)
    st_.mean = np.array([1.0, -2.0, 0.5, 3.0], np.float32)
    st_.var = np.array([4.0, 0.25, 1.0, 9.0], np.float32)
    v = RNG(0).randn(32, 4).astype(np.float32)
    w = st_.whiten(v)
    back = w * np.sqrt(st_.var + EPS) + st_.mean
    np.testing.assert_allclose(back, v, rtol=1e-5, atol=1e-5)


def test_raw_codewords_inverse_transform():
    st_ = VqState.init(4, 3, seed=2)
    st_.mean[:] = 5.0
    st_.var[:] = 4.0
    raw = st_.raw_codewords()
    np.testing.assert_allclose(
        raw, st_.cww * np.sqrt(4.0 + EPS) + 5.0, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.5, 0.999), st.floats(0.5, 0.999))
def test_ema_mass_conservation(seed, gamma, beta):
    """Cluster sizes stay positive and total EMA mass interpolates between
    old mass and the batch size (Alg. 2 lines 6-7)."""
    rng = RNG(seed)
    k, fp, b = 8, 4, 64
    st_ = VqState.init(k, fp, seed=seed)
    total0 = st_.counts.sum()
    v = rng.randn(b, fp).astype(np.float32)
    idx = assign(st_, v)
    vq_update(st_, v, idx, gamma, beta)
    total1 = st_.counts.sum()
    lo, hi = sorted([total0, float(b)])
    assert lo - 1e-3 <= total1 <= hi + 1e-3
    assert (st_.counts >= 0).all()


def test_online_kmeans_converges_to_planted_centroids():
    """Streaming updates on a 4-gaussian mixture recover the means."""
    rng = RNG(7)
    centers = np.array([[4, 4], [-4, 4], [4, -4], [-4, -4]], np.float32)
    st_ = VqState.init(4, 2, seed=3)
    # warm start near data scale so empty clusters don't stall
    st_.cww = centers * 0.1 + rng.randn(4, 2).astype(np.float32) * 0.1
    for _ in range(300):
        c = rng.randint(0, 4, 128)
        v = centers[c] + rng.randn(128, 2).astype(np.float32) * 0.3
        idx = assign(st_, v)
        vq_update(st_, v, idx, gamma=0.95, beta=0.95)
    raw = st_.raw_codewords()
    # each planted center must be within 0.3 of some codeword
    for c in centers:
        d = np.linalg.norm(raw - c, axis=1).min()
        assert d < 0.3, (c, raw)


def test_relative_error_decreases_with_k():
    """Paper App. C: VQ relative error ε shrinks as the codebook grows."""
    rng = RNG(11)
    x = rng.randn(2048, 8).astype(np.float32)
    errs = []
    for k in (2, 8, 32, 128):
        st_ = VqState.init(k, 8, seed=5)
        st_.cww = x[rng.choice(len(x), k, replace=False)].copy()
        st_.mean[:] = 0.0
        st_.var[:] = 1.0 - EPS
        for _ in range(60):
            sel = rng.choice(len(x), 256, replace=False)
            idx = assign(st_, x[sel])
            vq_update(st_, x[sel], idx, gamma=0.9, beta=1.0)
        idx = assign(st_, x)
        recon = st_.raw_codewords()[idx]
        errs.append(np.linalg.norm(x - recon) / np.linalg.norm(x))
    assert errs[0] > errs[1] > errs[2] > errs[3], errs


def test_empty_clusters_keep_position():
    st_ = VqState.init(4, 2, seed=9)
    st_.counts = np.array([1.0, 1.0, 0.0, 0.0], np.float32)
    before = st_.cww.copy()
    v = np.zeros((8, 2), np.float32)
    idx = np.zeros(8, np.int64)  # everything lands in cluster 0
    vq_update(st_, v, idx, gamma=0.5, beta=0.5)
    # clusters 2,3 got gamma-decayed counts below threshold on entry and
    # received no mass; with counts still > 0 after decay they may move, so
    # force the degenerate case explicitly:
    st2 = VqState.init(4, 2, seed=9)
    st2.counts = np.zeros(4, np.float32)
    st2.sums = np.zeros_like(st2.sums)
    before2 = st2.cww.copy()
    vq_update(st2, v, np.zeros(8, np.int64), gamma=1.0, beta=0.5)
    np.testing.assert_array_equal(st2.cww[1:], before2[1:])
