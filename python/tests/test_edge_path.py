"""Baseline (edge-list) path invariants: train/infer agreement, padding
neutrality, and isolation behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import edgemp
from compile.config import DATASETS, MODELS, TRAIN

RNG = np.random.RandomState


def _inputs(ins, nn, ds, rng):
    vals = []
    for name, shape, dt in ins:
        if name == "y":
            v = rng.randint(0, max(ds.n_classes, 2), shape).astype(np.int32)
        elif dt == "i32":
            v = rng.randint(0, nn, shape).astype(np.int32)
        elif name == "wloss":
            v = np.ones(shape, np.float32)
        elif name == "ecoef":
            v = (rng.rand(*shape) < 0.6).astype(np.float32) * 0.3
        else:
            v = (rng.randn(*shape) * 0.3).astype(np.float32)
        vals.append(v)
    return vals


@pytest.mark.parametrize("model_name", ["gcn", "sage", "gat"])
def test_edge_infer_matches_train_logits(model_name):
    ds = DATASETS["tiny_sim"]
    model = MODELS[model_name]
    nn, ne = 48, 320
    fn_t, ins_t, _ = edgemp.build_edge_train(ds, model, TRAIN, nn, ne)
    fn_i, ins_i, _ = edgemp.build_edge_infer(ds, model, TRAIN, nn, ne)
    rng = RNG(0)
    vals = _inputs(ins_t, nn, ds, rng)
    by = {n: v for (n, _, _), v in zip(ins_t, vals)}
    logits_t = np.asarray(fn_t(*[jnp.array(v) for v in vals])[1])
    logits_i = np.asarray(
        fn_i(*[jnp.array(by[n]) for n, _, _ in ins_i])[0]
    )
    np.testing.assert_allclose(logits_i, logits_t, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("model_name", ["gcn", "gat"])
def test_padding_edges_are_inert(model_name):
    """Edges with coef/validity 0 must not change any output row."""
    ds = DATASETS["tiny_sim"]
    model = MODELS[model_name]
    nn, ne = 32, 256
    fn, ins, _ = edgemp.build_edge_infer(ds, model, TRAIN, nn, ne)
    rng = RNG(1)
    vals = _inputs(ins, nn, ds, rng)
    idx = {n: i for i, (n, _, _) in enumerate(ins)}
    # zero out the last half of the edges
    vals[idx["ecoef"]][ne // 2:] = 0.0
    out1 = np.asarray(fn(*[jnp.array(v) for v in vals])[0])
    # retarget the dead edges at random other endpoints: must be a no-op
    vals2 = [v.copy() for v in vals]
    vals2[idx["esrc"]][ne // 2:] = rng.randint(0, nn, ne // 2)
    vals2[idx["edst"]][ne // 2:] = rng.randint(0, nn, ne // 2)
    out2 = np.asarray(fn(*[jnp.array(v) for v in vals2])[0])
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)


def test_isolated_node_gets_bias_only_gcn():
    ds = DATASETS["tiny_sim"]
    model = MODELS["gcn"]
    nn, ne = 8, 16
    fn, ins, _ = edgemp.build_edge_infer(ds, model, TRAIN, nn, ne)
    rng = RNG(2)
    vals = _inputs(ins, nn, ds, rng)
    idx = {n: i for i, (n, _, _) in enumerate(ins)}
    # no edges at all -> every node aggregates nothing; output = bias chain
    vals[idx["ecoef"]][:] = 0.0
    out = np.asarray(fn(*[jnp.array(v) for v in vals])[0])
    # all rows identical (pure bias propagation, no feature path)
    np.testing.assert_allclose(out, np.broadcast_to(out[0], out.shape),
                               rtol=1e-5, atol=1e-6)


def test_loss_mask_restricts_gradient_support():
    """wloss=0 nodes contribute no gradient: zeroing their labels must not
    change ∇params."""
    ds = DATASETS["tiny_sim"]
    model = MODELS["gcn"]
    nn, ne = 32, 128
    fn, ins, outs = edgemp.build_edge_train(ds, model, TRAIN, nn, ne)
    rng = RNG(3)
    vals = _inputs(ins, nn, ds, rng)
    idx = {n: i for i, (n, _, _) in enumerate(ins)}
    w = np.zeros(nn, np.float32)
    w[:8] = 1.0
    vals[idx["wloss"]] = w
    res1 = fn(*[jnp.array(v) for v in vals])
    vals2 = [v.copy() for v in vals]
    vals2[idx["y"]][8:] = 0  # change masked-out labels
    res2 = fn(*[jnp.array(v) for v in vals2])
    n_params = sum(1 for n, _, _ in ins if n.startswith("param."))
    for g1, g2 in zip(res1[-n_params:], res2[-n_params:]):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-6, atol=1e-7)
