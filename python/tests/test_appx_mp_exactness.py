"""The backbone correctness property of VQ-GNN (paper §4):

When the codebook is lossless — every out-of-batch node owns its own
codeword, feature codewords equal the true features, and gradient codewords
equal the true full-graph gradients — the approximated forward (Eq. 6) and
backward (Eq. 7) message passing must reproduce full-graph training EXACTLY.

This pins the custom-VJP boundary (`layers.mp_linear`) against jax autodiff
on the materialized dense convolution, layer by layer and through a 2-layer
network, for both single-branch and product-VQ layouts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.layers import mp_linear

RNG = np.random.RandomState


def _setup(seed, n=32, b=12, f=10, h=6, n_br=1):
    """Build a dense conv C, features X, weight W and a lossless codebook for
    the out-of-batch nodes of batch [0..b)."""
    rng = RNG(seed)
    C = rng.randn(n, n).astype(np.float32) * (rng.rand(n, n) < 0.3)
    X = rng.randn(n, f).astype(np.float32)
    W = rng.randn(f, h).astype(np.float32) / np.sqrt(f)
    out_idx = np.arange(b, n)
    k = len(out_idx)
    concat = f + h
    fp = -(-concat // n_br)
    F = n_br * fp
    c_in = C[:b, :b]
    c_out_cols = C[:b, b:]            # (b, k) out-of-batch columns
    ct_out_cols = C[b:, :b].T         # (b, k) transposed-conv columns
    # Lossless sketches: R = I over out-of-batch nodes, identical per branch.
    c_out = np.repeat(c_out_cols[None], n_br, axis=0).astype(np.float32)
    ct_out = np.repeat(ct_out_cols[None], n_br, axis=0).astype(np.float32)
    return C, X, W, c_in, c_out, ct_out, out_idx, (n_br, fp, F, k)


def _codewords(Xout, Gout, f, layout):
    """Pack true out-of-batch features ‖ gradients into branch codewords."""
    n_br, fp, F, k = layout
    z = np.zeros((k, F), np.float32)
    z[:, :f] = Xout
    z[:, f:f + Gout.shape[1]] = Gout
    return z.reshape(k, n_br, fp).transpose(1, 0, 2).copy()


@pytest.mark.parametrize("n_br", [1, 2, 4])
@pytest.mark.parametrize("seed", [0, 1])
def test_single_layer_exact(seed, n_br):
    n, b, f, h = 32, 12, 10, 6
    C, X, W, c_in, c_out, ct_out, out_idx, layout = _setup(seed, n, b, f, h, n_br)
    Cj, Xj, Wj = map(jnp.array, (C, X, W))
    tgt = jnp.array(RNG(seed + 99).randn(b, h).astype(np.float32))

    # Full-graph: loss = sum((C X W)[:b] * tgt); grads wrt X and W.
    def full(Xin, Win):
        y = (Cj @ Xin @ Win)[:b]
        return (y * tgt).sum()

    gX_full, gW_full = jax.grad(full, argnums=(0, 1))(Xj, Wj)
    y_full = (Cj @ Xj @ Wj)[:b]

    # True full-graph gradient codewords: G = dloss/d(CXW) rows, out-of-batch.
    G_all = np.zeros((n, h), np.float32)
    G_all[:b] = np.asarray(tgt)
    cw = _codewords(X[out_idx], G_all[out_idx], f, layout)

    def appx(xb, Win):
        y = mp_linear((f, h), xb, Win, jnp.array(c_in), jnp.array(c_out),
                      jnp.array(ct_out), jnp.array(cw))
        return (y * tgt).sum(), y

    (_, y_appx), (gxb, gW) = jax.value_and_grad(
        appx, argnums=(0, 1), has_aux=True)(Xj[:b], Wj)

    np.testing.assert_allclose(np.asarray(y_appx), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gxb), np.asarray(gX_full)[:b],
                               rtol=1e-4, atol=1e-4)
    # ∇W from the approximated path covers only the mini-batch rows of the
    # output; with a loss supported on the batch it matches full-graph ∇W.
    np.testing.assert_allclose(np.asarray(gW), np.asarray(gW_full),
                               rtol=1e-4, atol=1e-4)


def test_two_layer_exact_through_relu():
    """Stack two mp_linear layers with ReLU; lossless codebooks per layer
    must reproduce full-graph forward AND batch-node input gradients."""
    seed, n, b = 5, 40, 16
    f0, f1, f2 = 8, 6, 4
    rng = RNG(seed)
    C = (rng.randn(n, n) * (rng.rand(n, n) < 0.25)).astype(np.float32)
    X = rng.randn(n, f0).astype(np.float32)
    W0 = (rng.randn(f0, f1) / np.sqrt(f0)).astype(np.float32)
    W1 = (rng.randn(f1, f2) / np.sqrt(f1)).astype(np.float32)
    tgt = rng.randn(b, f2).astype(np.float32)
    Cj, Xj, W0j, W1j, tgtj = map(jnp.array, (C, X, W0, W1, tgt))

    def full(Xin, W0in, W1in):
        h1 = jax.nn.relu(Cj @ Xin @ W0in)
        y = (Cj @ h1 @ W1in)[:b]
        return (y * tgtj).sum(), (h1, y)

    (loss_full, (H1, y_full)), (gX, gW0, gW1) = jax.value_and_grad(
        full, argnums=(0, 1, 2), has_aux=True)(Xj, W0j, W1j)

    # Layer-wise true gradients for the gradient codewords.
    def full_pre(Xin, W0in, W1in):
        pre1 = Cj @ Xin @ W0in
        y = (Cj @ jax.nn.relu(pre1) @ W1in)[:b]
        return (y * tgtj).sum()

    gPre1 = jax.grad(
        lambda p: full_pre(Xj, W0j, W1j) if False else (
            (Cj @ jax.nn.relu(Cj @ Xj @ W0j + p) @ W1j)[:b] * tgtj).sum()
    )(jnp.zeros((n, f1)))
    G2 = np.zeros((n, f2), np.float32)
    G2[:b] = tgt

    out_idx = np.arange(b, n)
    lay0 = (1, f0 + f1, f0 + f1, n - b)
    lay1 = (1, f1 + f2, f1 + f2, n - b)
    cw0 = _codewords(X[out_idx], np.asarray(gPre1)[out_idx], f0, lay0)
    cw1 = _codewords(np.asarray(H1)[out_idx], G2[out_idx], f1, lay1)
    c_in = jnp.array(C[:b, :b])
    c_out = jnp.array(C[:b, b:][None])
    ct_out = jnp.array(C[b:, :b].T[None].copy())

    def appx(xb, W0in, W1in):
        h1 = jax.nn.relu(mp_linear((f0, f1), xb, W0in, c_in, c_out, ct_out,
                                   jnp.array(cw0)))
        y = mp_linear((f1, f2), h1, W1in, c_in, c_out, ct_out, jnp.array(cw1))
        return (y * tgtj).sum(), y

    (loss_appx, y_appx), (gxb, gW0a, gW1a) = jax.value_and_grad(
        appx, argnums=(0, 1, 2), has_aux=True)(Xj[:b], W0j, W1j)

    np.testing.assert_allclose(float(loss_appx), float(loss_full), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y_appx), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gxb), np.asarray(gX)[:b],
                               rtol=1e-3, atol=1e-4)
    # ∇W1 is exact; ∇W0 differs from full-graph by the out-of-batch rows of
    # the layer-0 output (whose W0-gradient full-graph training accumulates
    # but mini-batch training deliberately does not — paper App. C).
    np.testing.assert_allclose(np.asarray(gW1a), np.asarray(gW1),
                               rtol=1e-3, atol=1e-4)


def test_gradient_codewords_carry_blue_messages():
    """Zero gradient codewords must remove exactly the out-of-batch ("blue")
    backward messages: ∇X_B = C_inᵀ G_B Wᵀ only."""
    n, b, f, h = 24, 10, 6, 4
    C, X, W, c_in, c_out, ct_out, out_idx, layout = _setup(11, n, b, f, h, 1)
    tgt = RNG(12).randn(b, h).astype(np.float32)
    cw = _codewords(X[out_idx], np.zeros((n - b, h), np.float32), f, layout)

    def appx(xb):
        y = mp_linear((f, h), xb, jnp.array(W), jnp.array(c_in),
                      jnp.array(c_out), jnp.array(ct_out), jnp.array(cw))
        return (y * jnp.array(tgt)).sum()

    gxb = jax.grad(appx)(jnp.array(X[:b]))
    want = c_in.T @ tgt @ W.T
    np.testing.assert_allclose(np.asarray(gxb), want, rtol=1e-4, atol=1e-4)
